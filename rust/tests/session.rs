//! The session API contract: a `SessionBuilder`-composed run IS the legacy
//! hand-assembled `DistTrainer` run (bit-for-bit on the tiny preset), events
//! fire in order, `ExperimentConfig` drives a full session, and
//! checkpoint/resume continues a run exactly where it stopped.
//!
//! Determinism setup: rayon is pinned to one thread (set before any pool
//! exists in this test binary) so intra-op reduction splits cannot vary, and
//! every device runs a *virtual-time* throttle so calibration probes — and
//! therefore Eq. 1 shard tables — are identical across runs.  Under those
//! two pins the whole stack is deterministic and exact float comparison is
//! meaningful.

use std::sync::{Arc, Mutex, Once};

use convdist::cluster::{worker_loop, DistTrainer, WorkerOptions};
use convdist::config::{ExperimentConfig, TrainerConfig};
use convdist::data::default_dataset;
use convdist::devices::Throttle;
use convdist::net::{inproc_pair, Link};
use convdist::runtime::{ArchSpec, Runtime};
use convdist::sched::AdaptiveConfig;
use convdist::session::{Event, Session, SessionBuilder};

static SERIAL_RAYON: Once = Once::new();

/// Pin the global rayon pool to one thread.  Every test calls this first,
/// before any rayon use in the process, so the pool is built single-threaded
/// and adaptive iterator splitting (the one nondeterminism in the native
/// kernels' fold/reduce gradients) cannot occur.
fn serial_rayon() {
    SERIAL_RAYON.call_once(|| {
        std::env::set_var("RAYON_NUM_THREADS", "1");
    });
}

/// Virtual device speed for the tiny arch: slow enough that the virtual
/// duration dominates real compute (deterministic probes), fast enough that
/// a test run stays in milliseconds.
const VGF: f64 = 0.2;

fn vthrottle() -> Throttle {
    Throttle::virtual_gflops(VGF)
}

fn tiny_cfg(steps: usize) -> TrainerConfig {
    TrainerConfig {
        steps,
        lr: 0.05,
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 42,
        log_every: 100,
        calib_rounds: 1,
        checkpoint_every: None,
    }
}

fn tiny_session(steps: usize) -> Session {
    SessionBuilder::new()
        .arch_spec(ArchSpec::tiny())
        .trainer(tiny_cfg(steps))
        .master_throttle(vthrottle())
        .workers(&[vthrottle(), vthrottle()])
        .build()
        .unwrap()
}

/// The pre-session composition: hand-spawned worker threads over in-proc
/// links plus a directly constructed `DistTrainer` — what every example
/// used to inline.
fn legacy_worker(id: u32) -> Box<dyn Link> {
    let (master_end, worker_end) = inproc_pair();
    std::thread::Builder::new()
        .name(format!("legacy-worker-{id}"))
        .spawn(move || {
            let rt = Runtime::for_arch(ArchSpec::tiny());
            let _ = worker_loop(worker_end, rt, WorkerOptions::new(id, vthrottle()));
        })
        .unwrap();
    Box::new(master_end)
}

#[test]
fn session_reproduces_legacy_trainer_bit_for_bit() {
    serial_rayon();
    let steps = 4;
    let cfg = tiny_cfg(steps);
    let arch = ArchSpec::tiny();

    // Legacy-style run: manual links + DistTrainer + hand-rolled loop.
    let rt = Runtime::for_arch(arch.clone());
    let links = vec![legacy_worker(1), legacy_worker(2)];
    let mut legacy =
        DistTrainer::new(rt, links, &cfg, vthrottle(), AdaptiveConfig::disabled()).unwrap();
    let mut ds = default_dataset(arch.img, arch.in_ch, arch.num_classes, cfg.seed);
    let mut legacy_losses = Vec::new();
    for step in 0..steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        legacy_losses.push(legacy.step(&batch).unwrap().loss);
    }

    // Session-built run, same axes.
    let mut session = tiny_session(steps);
    assert_eq!(
        session.trainer().probe_times(),
        legacy.probe_times(),
        "virtual-time probes must be identical"
    );
    for layer in 1..=arch.num_convs() {
        assert_eq!(session.trainer().shards(layer), legacy.shards(layer));
    }
    let mut ds2 = default_dataset(arch.img, arch.in_ch, arch.num_classes, cfg.seed);
    for step in 0..steps {
        let batch = ds2.batch(arch.batch, step).unwrap();
        let loss = session.step(&batch).unwrap().loss;
        assert_eq!(
            loss.to_bits(),
            legacy_losses[step].to_bits(),
            "step {step}: session loss {loss} != legacy loss {}",
            legacy_losses[step]
        );
    }
    let diff = session.trainer().params.max_abs_diff(&legacy.params).unwrap();
    assert_eq!(diff, 0.0, "session and legacy params must be bit-identical");

    legacy.shutdown().unwrap();
    session.shutdown().unwrap();
}

#[test]
fn checkpoint_resume_equals_uninterrupted_run() {
    serial_rayon();
    let total = 6;
    let half = 3;

    // Uninterrupted reference: one session, `total` steps.
    let mut full = tiny_session(total);
    let full_report = full.run().unwrap();
    assert_eq!(full_report.steps_run, total);
    let full_params = full.trainer().params.to_named();
    full.shutdown().unwrap();

    // Interrupted run: `half` steps, checkpoint to disk, fresh session
    // resumes from the file and trains the remaining steps.
    let ckpt_path =
        std::env::temp_dir().join(format!("convdist-ckpt-{}.bin", std::process::id()));
    let mut first = tiny_session(half);
    let first_report = first.run().unwrap();
    first.save_checkpoint(&ckpt_path).unwrap();
    first.shutdown().unwrap();

    let mut resumed = SessionBuilder::new()
        .arch_spec(ArchSpec::tiny())
        .trainer(tiny_cfg(total - half))
        .master_throttle(vthrottle())
        .workers(&[vthrottle(), vthrottle()])
        .resume_from(&ckpt_path)
        .build()
        .unwrap();
    assert_eq!(resumed.trainer().steps_done(), half as u64);
    let resumed_report = resumed.run().unwrap();
    assert_eq!(resumed_report.first_step, half as u64);

    // The loss trajectory continues exactly: first half + resumed half ==
    // the uninterrupted run, bit for bit.
    let stitched: Vec<f32> = first_report
        .losses
        .iter()
        .chain(&resumed_report.losses)
        .copied()
        .collect();
    assert_eq!(stitched.len(), full_report.losses.len());
    for (i, (a, b)) in stitched.iter().zip(&full_report.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "step {i}: stitched {a} vs uninterrupted {b}");
    }
    // And so do the parameters (momentum state traveled through the file).
    let resumed_params = resumed.trainer().params.to_named();
    for ((na, ta), (nb, tb)) in resumed_params.iter().zip(&full_params) {
        assert_eq!(na, nb);
        assert!(
            ta.data().iter().zip(tb.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "param {na} diverged after resume"
        );
    }
    resumed.shutdown().unwrap();
    let _ = std::fs::remove_file(&ckpt_path);
}

#[test]
fn restore_rejects_wrong_architecture() {
    serial_rayon();
    let full = tiny_session(1);
    let ckpt = full.checkpoint();
    full.shutdown().unwrap();

    // A master-only tiny_deep session is cheap to build.
    let mut other = SessionBuilder::new()
        .arch_spec(ArchSpec::tiny_deep())
        .trainer(tiny_cfg(1))
        .master_throttle(vthrottle())
        .build()
        .unwrap();
    let err = other.restore(&ckpt).unwrap_err();
    assert!(format!("{err:#}").contains("arch"), "unhelpful error: {err:#}");
    other.shutdown().unwrap();
}

#[test]
fn events_fire_in_order_with_checkpoint_and_eval() {
    serial_rayon();
    let log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = log.clone();
    let mut session = SessionBuilder::new()
        .arch_spec(ArchSpec::tiny())
        .trainer(tiny_cfg(2))
        .master_throttle(vthrottle())
        .workers(&[vthrottle()])
        .on_event(move |ev| {
            let tag = match ev {
                Event::StepCompleted { step, loss, devices, .. } => {
                    assert!(loss.is_finite());
                    assert_eq!(*devices, 2);
                    format!("step{step}")
                }
                Event::Repartitioned { .. } => "repartition".into(),
                Event::Rebalanced { .. } => "rebalance".into(),
                Event::WorkerLeft { .. } => "left".into(),
                Event::EvalDone { accuracy, .. } => {
                    assert!((0.0..=1.0).contains(accuracy));
                    "eval".into()
                }
                Event::CheckpointSaved { step, .. } => format!("ckpt{step}"),
                Event::HealthChanged { device, to, .. } => {
                    format!("health:dev{device}:{}", to.label())
                }
                Event::AnomalyFlagged { step, .. } => format!("anomaly{step}"),
            };
            sink.lock().unwrap().push(tag);
        })
        .build()
        .unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.steps_run, 2);
    let ckpt_path =
        std::env::temp_dir().join(format!("convdist-ev-ckpt-{}.bin", std::process::id()));
    session.save_checkpoint(&ckpt_path).unwrap();
    session.shutdown().unwrap();
    let _ = std::fs::remove_file(&ckpt_path);

    let got = log.lock().unwrap().clone();
    assert_eq!(got, vec!["step1", "step2", "eval", "ckpt2"]);
}

#[test]
fn experiment_config_drives_a_full_session() {
    serial_rayon();
    // The serialized-builder form: a JSON config with an arch preset maps
    // onto the same axes and runs end to end (`convdist run --config`).
    let cfg = ExperimentConfig::from_json_str(
        r#"{
          "name": "session-test",
          "arch": "tiny",
          "trainer": {"steps": 2, "calib_rounds": 1, "log_every": 1},
          "cluster": {"workers": 1, "devices": "uniform"}
        }"#,
    )
    .unwrap();
    let mut session = SessionBuilder::from_experiment(&cfg).unwrap().build().unwrap();
    assert_eq!(session.runtime().arch().label(), "4:8");
    let report = session.run().unwrap();
    assert_eq!(report.steps_run, 2);
    assert!(report.final_loss().is_finite());
    assert!(report.bytes_moved > 0, "one worker must move bytes");
    session.shutdown().unwrap();
}
