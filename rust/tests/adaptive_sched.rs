//! Integration tests of the adaptive scheduling subsystem on throttled
//! in-proc clusters: telemetry-driven re-partitioning after a mid-run 8x
//! degradation, elastic membership (graceful `Leave`, gather-deadline
//! drops), and the static-path regression guarantee when adaptation is off.
//! Fleets compose through `SessionBuilder` (`worker_plans` + `adaptive`);
//! the custom worker harnesses ride in through `SessionBuilder::links`.

mod common;

use std::time::{Duration, Instant};

use convdist::cluster::{worker_loop, WorkerOptions};
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::{Throttle, ThrottlePlan};
use convdist::net::{inproc_pair, Link};
use convdist::proto::Message;
use convdist::runtime::Runtime;
use convdist::sched::{partition_layer, AdaptiveConfig};
use convdist::session::SessionBuilder;

/// A healthy library worker on an in-proc link, optionally scripted to
/// leave gracefully after `leave_after` ConvWork frames.
fn spawn_library_worker(id: u32, leave_after: Option<u64>) -> Box<dyn Link> {
    let (master_end, worker_end) = inproc_pair();
    std::thread::spawn(move || {
        let rt = Runtime::open(convdist::artifacts_dir()).unwrap();
        let mut opts = WorkerOptions::new(id, Throttle::none());
        opts.leave_after = leave_after;
        let _ = worker_loop(worker_end, rt, opts);
    });
    Box::new(master_end)
}

/// A worker that serves calibration and `live` ConvWork frames, then wedges
/// — keeps the link open but never replies again (a silent straggler, not a
/// crash).
fn spawn_hanging_worker(id: u32, live: usize) -> Box<dyn Link> {
    let (master_end, mut worker_end) = inproc_pair();
    std::thread::spawn(move || {
        let rt = Runtime::open(convdist::artifacts_dir()).unwrap();
        worker_end.send(&Message::Hello { worker_id: id, version: 1 }).unwrap();
        let mut served = 0usize;
        loop {
            match worker_end.recv() {
                Ok(Message::Calibrate { .. }) => {
                    worker_end.send(&Message::CalibrateResult { seconds: 0.01 }).unwrap();
                }
                Ok(Message::ConvWork { seq, layer, dir, bucket, inputs, kernels, extra }) => {
                    if served >= live {
                        loop {
                            std::thread::sleep(Duration::from_secs(3600));
                        }
                    }
                    served += 1;
                    let reply = convdist::cluster::compute_conv_work(
                        &rt,
                        Throttle::none(),
                        seq,
                        layer,
                        dir,
                        bucket as usize,
                        inputs,
                        kernels,
                        extra,
                    )
                    .unwrap();
                    worker_end.send(&reply).unwrap();
                }
                Ok(Message::AllOk) | Ok(Message::ShardUpdate { .. }) => {}
                Ok(Message::TrainOver) | Err(_) => return,
                Ok(other) => panic!("unexpected {other:?}"),
            }
        }
    });
    Box::new(master_end)
}

/// The headline scenario: a 4-device virtual fleet where one worker
/// degrades 8x at step 3.  The policy must re-balance within the cooldown
/// window and the steady-state step time must land within 25% of the static
/// oracle calibrated on the already-degraded fleet.
#[test]
fn degraded_worker_triggers_repartition_and_recovers_near_oracle() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(12);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 21);

    let fast = Throttle::virtual_gflops(2.0);
    let slow = Throttle::virtual_gflops(0.25); // 8x degradation
    // Worker 0 (device 1) degrades after 3 steps (4 conv calls per step).
    let plans = vec![
        ThrottlePlan::degrade_after(fast, 12, slow),
        ThrottlePlan::fixed(fast),
        ThrottlePlan::fixed(fast),
    ];
    let adaptive = AdaptiveConfig {
        alpha: 0.5,
        warmup_steps: 1,
        imbalance_threshold: 0.2,
        hysteresis: 0.05,
        cooldown_steps: 2,
        heartbeat_every: 0,
        ..Default::default()
    };
    let mut dist = SessionBuilder::new()
        .trainer(cfg.clone())
        .master_throttle(fast)
        .worker_plans(plans)
        .adaptive(adaptive)
        .build()
        .unwrap();

    let pre_shard =
        dist.trainer().shards(2).iter().find(|s| s.device == 1).map(|s| s.len()).unwrap_or(0);
    assert!(pre_shard > 0, "equal fleet must give worker 1 a layer-2 shard");
    let mut repartition_step = None;
    let mut step_secs = Vec::new();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let t0 = Instant::now();
        let r = dist.step(&batch).unwrap();
        step_secs.push(t0.elapsed().as_secs_f64());
        assert!(r.loss.is_finite());
        if r.repartitioned && repartition_step.is_none() {
            repartition_step = Some(step);
        }
    }
    // Re-balanced within the cooldown window of the degradation (the event
    // lands in step 3; warmup 1 + cooldown 2 + slack).
    let when = repartition_step.expect("degradation never triggered a re-shard");
    assert!((3..=7).contains(&when), "re-shard at step {when}, expected 3..=7");
    let post_shard =
        dist.trainer().shards(2).iter().find(|s| s.device == 1).map(|s| s.len()).unwrap_or(0);
    assert!(
        post_shard < pre_shard,
        "slow device's shard must shrink: {pre_shard} -> {post_shard}"
    );
    let stats = dist.trainer().sched_stats().clone();
    assert!(stats.repartitions >= 1, "{stats}");
    assert!(stats.straggler_flags >= 1, "8x straggler never flagged: {stats}");
    assert_eq!(stats.departures, 0, "{stats}");
    assert_eq!(stats.utilization.len(), 4, "{stats}");
    dist.shutdown().unwrap();

    // Static oracle for the degraded fleet: a fresh session whose
    // calibration already sees the slow device.
    let mut oracle = SessionBuilder::new()
        .trainer(cfg.clone())
        .master_throttle(fast)
        .workers(&[slow, fast, fast])
        .build()
        .unwrap();
    let mut oracle_secs = Vec::new();
    for step in 0..5 {
        let batch = ds.batch(arch.batch, step).unwrap();
        let t0 = Instant::now();
        oracle.step(&batch).unwrap();
        oracle_secs.push(t0.elapsed().as_secs_f64());
    }
    oracle.shutdown().unwrap();

    // Steady state (last 4 adaptive steps, well past the re-shard) within
    // 25% of the oracle (skipping its first step: executable preparation).
    let tail = &step_secs[step_secs.len() - 4..];
    let adaptive_mean = tail.iter().sum::<f64>() / tail.len() as f64;
    let otail = &oracle_secs[1..];
    let oracle_mean = otail.iter().sum::<f64>() / otail.len() as f64;
    assert!(
        adaptive_mean <= oracle_mean * 1.25,
        "adaptive steady state {adaptive_mean:.3}s vs oracle {oracle_mean:.3}s"
    );
}

/// Elastic membership, graceful flavor: a worker announces `Leave`
/// mid-epoch; the master re-absorbs its kernel range and the loss
/// trajectory matches a fleet that started without it (same seed).
#[test]
fn worker_leave_mid_epoch_matches_smaller_fleet_trajectory() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(6);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 33);

    // Worker 1 leaves during step 1 (after 6 of its ConvWork frames).
    let links: Vec<Box<dyn Link>> =
        vec![spawn_library_worker(1, Some(6)), spawn_library_worker(2, None)];
    // Unthrottled in-proc timings are noisy; a sky-high imbalance threshold
    // pins the policy so this test isolates the membership path.
    let adaptive =
        AdaptiveConfig { imbalance_threshold: 5.0, heartbeat_every: 0, ..Default::default() };
    let mut dist = SessionBuilder::new()
        .trainer(cfg.clone())
        .links(links)
        .adaptive(adaptive)
        .build()
        .unwrap();
    let mut losses = Vec::new();
    let mut left_events = 0usize;
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let before = 1 + dist.trainer().alive_workers();
        let r = dist.step(&batch).unwrap();
        if r.devices < before {
            left_events += 1;
        }
        losses.push(r.loss);
    }
    assert_eq!(dist.trainer().alive_workers(), 1);
    assert_eq!(dist.trainer().sched_stats().departures, 1);
    assert_eq!(left_events, 1, "the departure must surface in exactly one step result");
    // The departed device's range was re-absorbed by the survivors.
    for layer in [1usize, 2] {
        let covered: usize = dist.trainer().shards(layer).iter().map(|s| s.len()).sum();
        assert_eq!(covered, arch.kernels(layer));
        assert!(
            dist.trainer().shards(layer).iter().all(|s| s.device != 1),
            "left device scheduled"
        );
    }
    dist.shutdown().unwrap();

    // Reference run that started with one fewer worker, same seed.
    let mut ds2 = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 33);
    let links2: Vec<Box<dyn Link>> = vec![spawn_library_worker(1, None)];
    let mut smaller =
        SessionBuilder::new().trainer(cfg.clone()).links(links2).build().unwrap();
    let mut ref_losses = Vec::new();
    for step in 0..cfg.steps {
        let batch = ds2.batch(arch.batch, step).unwrap();
        ref_losses.push(smaller.step(&batch).unwrap().loss);
    }
    smaller.shutdown().unwrap();
    for (i, (a, b)) in losses.iter().zip(&ref_losses).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * b.abs().max(1.0),
            "step {i}: left-mid-epoch {a} vs smaller fleet {b}"
        );
    }
}

/// Elastic membership, silent flavor: a wedged worker (link open, no
/// replies) is dropped when it blows the gather deadline, and training
/// completes on the survivors.
#[test]
fn hung_worker_is_dropped_on_gather_deadline() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(3);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 44);

    let links: Vec<Box<dyn Link>> =
        vec![spawn_hanging_worker(1, 4), spawn_library_worker(2, None)];
    let adaptive = AdaptiveConfig {
        gather_timeout: Some(Duration::from_millis(500)),
        heartbeat_every: 0,
        ..Default::default()
    };
    let mut dist = SessionBuilder::new()
        .trainer(cfg.clone())
        .links(links)
        .adaptive(adaptive)
        .build()
        .unwrap();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let r = dist.step(&batch).unwrap();
        assert!(r.loss.is_finite());
    }
    assert_eq!(dist.trainer().alive_workers(), 1);
    assert_eq!(dist.trainer().sched_stats().departures, 1);
    for layer in [1usize, 2] {
        assert!(
            dist.trainer().shards(layer).iter().all(|s| s.device != 1),
            "hung device scheduled"
        );
    }
    dist.shutdown().unwrap();
    // The wedged worker thread is reaped with the test process.
}

/// The regression guarantee: with adaptation disabled the scheduler IS the
/// static paper path — same probe times give bit-identical shard tables
/// (checked against the pure partitioner), a mid-run degradation moves
/// nothing, and the numerics match to float-reassociation noise.
#[test]
fn adaptation_disabled_is_identical_to_static_path() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(3);

    // Virtual-time probe padding makes calibration deterministic (the
    // virtual duration comfortably dominates the real probe compute even
    // under CI contention), so both trainers observe identical probe times
    // and exact table comparison is meaningful.
    let v = Throttle::virtual_gflops(0.5);
    let degrading = ThrottlePlan::degrade_after(v, 8, Throttle::virtual_gflops(0.25));
    let plans = vec![degrading, ThrottlePlan::fixed(v)];

    let mut stat = SessionBuilder::new()
        .trainer(cfg.clone())
        .master_throttle(v)
        .worker_plans(plans.clone())
        .build()
        .unwrap();
    let mut off = SessionBuilder::new()
        .trainer(cfg.clone())
        .master_throttle(v)
        .worker_plans(plans)
        .adaptive(AdaptiveConfig::disabled())
        .build()
        .unwrap();

    assert_eq!(
        stat.trainer().probe_times(),
        off.trainer().probe_times(),
        "virtual probes must be deterministic"
    );
    for layer in [1usize, 2] {
        assert_eq!(stat.trainer().shards(layer), off.trainer().shards(layer));
        // The disabled path is the pure Eq. 1 partitioner, nothing more.
        let direct = partition_layer(
            arch.kernels(layer),
            off.trainer().probe_times(),
            arch.buckets(layer),
        )
        .unwrap();
        assert_eq!(off.trainer().shards(layer), &direct[..]);
    }
    let initial1 = stat.trainer().shards(1).to_vec();
    let initial2 = stat.trainer().shards(2).to_vec();

    let mut ds_a = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 55);
    let mut ds_b = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 55);
    for step in 0..cfg.steps {
        let la = stat.step(&ds_a.batch(arch.batch, step).unwrap()).unwrap().loss;
        let rb = off.step(&ds_b.batch(arch.batch, step).unwrap()).unwrap();
        assert!(!rb.repartitioned, "disabled mode must never re-shard");
        // Same executables on the same inputs: losses agree to float
        // reassociation noise (rayon reduction order is not pinned).
        assert!(
            (la - rb.loss).abs() < 1e-4 * la.abs().max(1.0),
            "step {step}: static {la} vs disabled-adaptive {}",
            rb.loss
        );
    }
    // The mid-run degradation must NOT move the tables when adaptation is
    // off — exactly the static paper behavior.
    assert_eq!(off.trainer().shards(1), &initial1[..]);
    assert_eq!(off.trainer().shards(2), &initial2[..]);
    assert_eq!(off.trainer().sched_stats().repartitions, 0);
    assert_eq!(off.trainer().sched_stats().straggler_flags, 0);
    let diff = stat.trainer().params.max_abs_diff(&off.trainer().params).unwrap();
    assert!(diff < 1e-4, "param divergence with adaptation off: {diff}");
    stat.shutdown().unwrap();
    off.shutdown().unwrap();
}
