//! Finite-difference gradient checks for the native backward kernels —
//! `conv2d_bwd`, `maxpool2_bwd` and `lrn_bwd` against central differences
//! of the scalar loss `L = <gy, fwd(x)>`.  These close the loop the
//! adjoint/inner-product identities in the unit tests leave open: a bug
//! that preserves linear structure (e.g. a transposed index that is its own
//! adjoint) still shifts individual FD probes.

use convdist::kernels as k;
use convdist::tensor::Pcg32;

fn randn(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gaussian()).collect()
}

/// f64 inner product of f32 slices (FD noise floor control).
fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

#[test]
fn conv2d_bwd_input_and_kernel_grads_match_finite_differences() {
    let mut rng = Pcg32::seed(3001);
    let (b, c, h, kk, kh) = (2usize, 3usize, 8usize, 5usize, 3usize);
    let oh = h - kh + 1;
    let x = randn(&mut rng, b * c * h * h);
    let w = randn(&mut rng, kk * c * kh * kh);
    let bias = randn(&mut rng, kk);
    let gy = randn(&mut rng, b * kk * oh * oh);
    let (gx, gw, gb) = k::conv2d_bwd(&x, &w, &gy, b, c, h, h, kk, kh, kh);

    let loss = |xs: &[f32], ws: &[f32]| -> f64 {
        let y = k::conv2d_fwd(xs, ws, &bias, b, c, h, h, kk, kh, kh);
        dot64(&y, &gy)
    };
    let eps = 1e-2f32;
    // Conv is linear in x and w, so central differences are exact up to
    // f32 rounding of the forward pass itself.
    for &p in &[0usize, 17, 101, b * c * h * h - 1] {
        let mut xp = x.clone();
        xp[p] += eps;
        let mut xm = x.clone();
        xm[p] -= eps;
        let fd = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps as f64);
        let got = gx[p] as f64;
        assert!(
            (got - fd).abs() <= 1e-2 * fd.abs().max(1.0),
            "gx[{p}]: analytic {got} vs fd {fd}"
        );
    }
    for &p in &[0usize, 7, 50, kk * c * kh * kh - 1] {
        let mut wp = w.clone();
        wp[p] += eps;
        let mut wm = w.clone();
        wm[p] -= eps;
        let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
        let got = gw[p] as f64;
        assert!(
            (got - fd).abs() <= 1e-2 * fd.abs().max(1.0),
            "gw[{p}]: analytic {got} vs fd {fd}"
        );
    }
    // Bias gradient: d<gy, y>/d bias[ki] = sum of gy over kernel ki.
    for ki in 0..kk {
        let want: f64 = (0..b)
            .map(|bi| {
                gy[(bi * kk + ki) * oh * oh..(bi * kk + ki + 1) * oh * oh]
                    .iter()
                    .map(|&v| v as f64)
                    .sum::<f64>()
            })
            .sum();
        assert!((gb[ki] as f64 - want).abs() < 1e-3, "gb[{ki}]");
    }
}

#[test]
fn maxpool2_bwd_matches_finite_differences() {
    // Deterministic well-separated values (multiples of 0.05, all distinct
    // per image thanks to gcd(53, 191) = 1): no window ever has a tie
    // within the FD epsilon, so the subgradient is the gradient.
    let (b, c, h) = (2usize, 2usize, 6usize);
    let n = b * c * h * h;
    let x: Vec<f32> = (0..n).map(|i| ((i * 53) % 191) as f32 * 0.05 - 4.0).collect();
    let mut rng = Pcg32::seed(3002);
    let gp = randn(&mut rng, b * c * (h / 2) * (h / 2));
    let gx = k::maxpool2_bwd(&x, &gp, b, c, h, h);

    let loss = |xs: &[f32]| -> f64 { dot64(&k::maxpool2_fwd(xs, b, c, h, h), &gp) };
    let eps = 1e-3f32;
    for &p in &[0usize, 5, 36, 77, n - 1] {
        let mut xp = x.clone();
        xp[p] += eps;
        let mut xm = x.clone();
        xm[p] -= eps;
        let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
        let got = gx[p] as f64;
        assert!(
            (got - fd).abs() <= 1e-2 * fd.abs().max(1.0),
            "pool gx[{p}]: analytic {got} vs fd {fd}"
        );
    }
    // Every pooled gradient lands somewhere: mass is conserved.
    let routed: f64 = gx.iter().map(|&v| v as f64).sum();
    let injected: f64 = gp.iter().map(|&v| v as f64).sum();
    assert!((routed - injected).abs() < 1e-4);
}

/// f64 LRN forward (the clipped-window formula from `kernels`), for FD that
/// is not drowned by f32 noise.
fn lrn_fwd_f64(x: &[f64], c: usize, hw: usize) -> Vec<f64> {
    let half = k::LRN_N / 2;
    let mut y = vec![0f64; x.len()];
    for p in 0..hw {
        for ci in 0..c {
            let (lo, hi) = (ci.saturating_sub(half), (ci + k::LRN_N - 1 - half).min(c - 1));
            let mut s = 0f64;
            for j in lo..=hi {
                s += x[j * hw + p] * x[j * hw + p];
            }
            let d = k::LRN_K as f64 + k::LRN_ALPHA as f64 * s;
            y[ci * hw + p] = x[ci * hw + p] * d.powf(-(k::LRN_BETA as f64));
        }
    }
    y
}

#[test]
fn lrn_bwd_matches_finite_differences() {
    let mut rng = Pcg32::seed(3003);
    let (c, h) = (7usize, 4usize);
    let hw = h * h;
    let x = randn(&mut rng, c * hw);
    let gy = randn(&mut rng, c * hw);
    let gx = k::lrn_bwd(&x, &gy, 1, c, h, h);
    let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let eps = 1e-4f64;
    for &p in &[0usize, 3, hw, 2 * hw + 5, 5 * hw + 1, c * hw - 1] {
        let mut xp = x64.clone();
        xp[p] += eps;
        let mut xm = x64.clone();
        xm[p] -= eps;
        let lp: f64 = lrn_fwd_f64(&xp, c, hw).iter().zip(&gy).map(|(a, &g)| a * g as f64).sum();
        let lm: f64 = lrn_fwd_f64(&xm, c, hw).iter().zip(&gy).map(|(a, &g)| a * g as f64).sum();
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (gx[p] as f64 - fd).abs() < 1e-3,
            "lrn gx[{p}]: analytic {} vs fd {fd}",
            gx[p]
        );
    }
}
