//! Native-backend conformance + the tier-1 end-to-end check.
//!
//! The conformance half mirrors `python/compile/kernels/ref.py`: the
//! runtime's conv fwd / input-grad / kernel-grad must agree with a direct
//! 7-loop reference to <= 1e-4 max-abs-diff.  The e2e half runs a few train
//! steps on `ArchSpec::tiny` and asserts (a) the loss decreases and (b) an
//! in-proc distributed run over 3 *heterogeneous* workers matches
//! single-device training to <= 1e-4 in every parameter.
//!
//! No artifacts, no Python, no network: everything here runs on the pure
//! rust backend.

use std::sync::Arc;

use convdist::baselines::SingleDeviceTrainer;
use convdist::cluster::{worker_loop, DistTrainer, WorkerOptions};
use convdist::config::TrainerConfig;
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::net::{inproc_pair, Link};
use convdist::runtime::{ArchSpec, Runtime};
use convdist::sched::AdaptiveConfig;
use convdist::tensor::{Pcg32, Tensor, Value};

fn tiny_runtime() -> Arc<Runtime> {
    Runtime::for_arch(ArchSpec::tiny())
}

fn tiny_cfg(steps: usize, momentum: f32) -> TrainerConfig {
    TrainerConfig {
        steps,
        lr: 0.05,
        momentum,
        weight_decay: 0.0,
        seed: 42,
        log_every: 1000,
        calib_rounds: 1,
        checkpoint_every: None,
    }
}

/// A worker thread over an in-proc link, with its own tiny-arch runtime
/// (one runtime per device, like the TCP deployment).
fn spawn_tiny_worker(id: u32, throttle: Throttle) -> Box<dyn Link> {
    let (master_end, worker_end) = inproc_pair();
    std::thread::Builder::new()
        .name(format!("tiny-worker-{id}"))
        .spawn(move || {
            let rt = Runtime::for_arch(ArchSpec::tiny());
            let _ = worker_loop(worker_end, rt, WorkerOptions::new(id, throttle));
        })
        .expect("spawning tiny worker");
    Box::new(master_end)
}

// ---------------------------------------------------------------------------
// Direct reference implementations (the in-test analogue of ref.py)
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn conv_ref(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    c: usize,
    h: usize,
    k: usize,
    kh: usize,
) -> Vec<f32> {
    let oh = h - kh + 1;
    let mut y = vec![0f32; b * k * oh * oh];
    for bi in 0..b {
        for ki in 0..k {
            for oi in 0..oh {
                for oj in 0..oh {
                    let mut acc = bias[ki];
                    for ci in 0..c {
                        for di in 0..kh {
                            for dj in 0..kh {
                                acc += x[((bi * c + ci) * h + oi + di) * h + oj + dj]
                                    * w[((ki * c + ci) * kh + di) * kh + dj];
                            }
                        }
                    }
                    y[((bi * k + ki) * oh + oi) * oh + oj] = acc;
                }
            }
        }
    }
    y
}

/// Reference adjoints straight from the cross-correlation definition.
#[allow(clippy::too_many_arguments)]
fn conv_bwd_ref(
    x: &[f32],
    w: &[f32],
    gy: &[f32],
    b: usize,
    c: usize,
    h: usize,
    k: usize,
    kh: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let oh = h - kh + 1;
    let mut gx = vec![0f32; b * c * h * h];
    let mut gw = vec![0f32; k * c * kh * kh];
    let mut gb = vec![0f32; k];
    for bi in 0..b {
        for ki in 0..k {
            for oi in 0..oh {
                for oj in 0..oh {
                    let g = gy[((bi * k + ki) * oh + oi) * oh + oj];
                    gb[ki] += g;
                    for ci in 0..c {
                        for di in 0..kh {
                            for dj in 0..kh {
                                gx[((bi * c + ci) * h + oi + di) * h + oj + dj] +=
                                    g * w[((ki * c + ci) * kh + di) * kh + dj];
                                gw[((ki * c + ci) * kh + di) * kh + dj] +=
                                    g * x[((bi * c + ci) * h + oi + di) * h + oj + dj];
                            }
                        }
                    }
                }
            }
        }
    }
    (gx, gw, gb)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn runtime_conv_fwd_and_grads_match_reference_within_1e4() {
    let rt = tiny_runtime();
    let a = rt.arch().clone();
    let (b, c, h, k, kh) = (a.batch, a.in_ch, a.img, a.kernels(1), a.conv_kernel(1).0);
    let mut rng = Pcg32::seed(77);
    let x = Tensor::randn(&[b, c, h, h], &mut rng);
    let w = Tensor::randn(&[k, c, kh, kh], &mut rng);
    let bias = Tensor::randn(&[k], &mut rng);

    // Forward through the runtime dispatch path.
    let outs = rt
        .execute(
            "conv1_fwd_b4",
            &[
                Value::F32(x.clone()),
                Value::F32(w.clone()),
                Value::F32(bias.clone()),
            ],
        )
        .unwrap();
    let y = outs[0].as_f32().unwrap();
    let want = conv_ref(x.data(), w.data(), bias.data(), b, c, h, k, kh);
    assert!(
        max_abs_diff(y.data(), &want) <= 1e-4,
        "conv fwd diverges from the ref.py-style oracle"
    );

    // Backward: input-grad and kernel-grad.
    let oh = h - kh + 1;
    let gy = Tensor::randn(&[b, k, oh, oh], &mut rng);
    let outs = rt
        .execute(
            "conv1_bwd_b4",
            &[Value::F32(x.clone()), Value::F32(w.clone()), Value::F32(gy.clone())],
        )
        .unwrap();
    let (wgx, wgw, wgb) = conv_bwd_ref(x.data(), w.data(), gy.data(), b, c, h, k, kh);
    assert!(max_abs_diff(outs[0].as_f32().unwrap().data(), &wgx) <= 1e-4, "input-grad");
    assert!(max_abs_diff(outs[1].as_f32().unwrap().data(), &wgw) <= 1e-4, "kernel-grad");
    assert!(max_abs_diff(outs[2].as_f32().unwrap().data(), &wgb) <= 1e-4, "bias-grad");
}

#[test]
fn tiny_arch_training_loss_decreases() {
    // Full-batch descent on one fixed batch must reduce the loss.
    let rt = tiny_runtime();
    let arch = rt.arch().clone();
    let cfg = tiny_cfg(6, 0.0);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 3);
    let batch = ds.batch(arch.batch, 0).unwrap();
    let mut t = SingleDeviceTrainer::new(rt, &cfg, Throttle::none()).unwrap();
    let (first, _) = t.step(&batch).unwrap();
    let mut last = first;
    for _ in 1..cfg.steps {
        last = t.step(&batch).unwrap().0;
    }
    assert!(
        last < first,
        "loss must decrease on repeated batch: {first} -> {last}"
    );
    assert!(first.is_finite() && last.is_finite());
}

#[test]
fn tiny_arch_distributed_heterogeneous_matches_single_within_1e4() {
    let rt = tiny_runtime();
    let arch = rt.arch().clone();
    let cfg = tiny_cfg(3, 0.9);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 5);

    // 3 heterogeneous workers: native speed, 2x slower, 4x slower.
    let links: Vec<Box<dyn Link>> = vec![
        spawn_tiny_worker(1, Throttle::none()),
        spawn_tiny_worker(2, Throttle::new(2.0)),
        spawn_tiny_worker(3, Throttle::new(4.0)),
    ];
    let mut dist =
        DistTrainer::new(rt.clone(), links, &cfg, Throttle::none(), AdaptiveConfig::disabled())
            .unwrap();
    let mut single = SingleDeviceTrainer::new(rt.clone(), &cfg, Throttle::none()).unwrap();

    // Every layer is fully covered by the Eq. 1 partition.
    for layer in [1usize, 2] {
        let covered: usize = dist.shards(layer).iter().map(|s| s.len()).sum();
        assert_eq!(covered, arch.kernels(layer));
    }

    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let r = dist.step(&batch).unwrap();
        assert_eq!(r.devices, 4);
        let (sl, _) = single.step(&batch).unwrap();
        assert!(
            (r.loss - sl).abs() <= 1e-4 * sl.abs().max(1.0),
            "step {step}: distributed loss {} vs single {sl}",
            r.loss
        );
    }
    let diff = dist.params.max_abs_diff(&single.params).unwrap();
    assert!(
        diff <= 1e-4,
        "distributed vs single-device params diverged: {diff}"
    );

    // Achieved-GFLOP/s observability rides along even with adaptation off:
    // the master saw fwd+bwd conv executions, so per-op rates exist and are
    // positive/finite.
    let stats = dist.sched_stats();
    assert!(!stats.op_gflops.is_empty(), "per-op GFLOP/s must be recorded");
    for (op, rate) in &stats.op_gflops {
        assert!(rate.is_finite() && *rate > 0.0, "op {op} rate {rate}");
    }

    // The eval path (eval_full) composes too.
    let held_out = ds.batch(arch.batch, 999).unwrap();
    let acc = dist.eval_accuracy(&held_out).unwrap();
    assert!((0.0..=1.0).contains(&acc));

    dist.shutdown().unwrap();
}
