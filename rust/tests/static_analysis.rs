//! Tier-1 gate over the static analyzer (DESIGN.md §10): every fixture in
//! `tests/fixtures/bad_graphs/` must fail with exactly the diagnostic code
//! its filename documents, and everything the repo ships — arch presets and
//! `examples/configs/` — must check clean.

use std::path::{Path, PathBuf};

use convdist::analysis::{self, lookup, Severity};
use convdist::config::ExperimentConfig;
use convdist::runtime::ArchSpec;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bad_graphs")
}

fn default_plan_options(cfg: &ExperimentConfig) -> analysis::PlanCheckOptions {
    analysis::PlanCheckOptions {
        bandwidth_mbps: cfg.network.bandwidth_mbps,
        adaptive: Some(cfg.adaptive),
    }
}

/// The corpus contract: `<CODE>_<slug>.json` must produce `<CODE>`, and when
/// the registry says the code is deny-level the report must actually deny.
#[test]
fn every_bad_fixture_fails_with_its_documented_code() {
    let mut checked = 0usize;
    for entry in std::fs::read_dir(fixture_dir()).expect("fixture dir must exist") {
        let path = entry.unwrap().path();
        if !path.extension().is_some_and(|e| e == "json") {
            continue;
        }
        let stem = path.file_stem().unwrap().to_str().unwrap().to_string();
        let code = stem.split('_').next().unwrap().to_string();
        let (severity, _) = lookup(&code)
            .unwrap_or_else(|| panic!("fixture {stem} names unregistered code {code}"));
        let text = std::fs::read_to_string(&path).unwrap();
        // Filename prefix doubles as the document type: C-codes are
        // experiment configs, G-codes are standalone graph documents.
        let rep = if code.starts_with('C') {
            analysis::check_config_text(&text)
        } else {
            analysis::check_graph_text(&text)
        };
        assert!(
            rep.diags.iter().any(|d| d.code == code),
            "{stem}: expected {code}, got:\n{}",
            rep.render_human()
        );
        if severity == Severity::Deny {
            assert!(rep.has_deny(), "{stem}: {code} is deny-level but report passes");
        } else {
            assert!(
                !rep.has_deny(),
                "{stem}: {code} is a lint, but the fixture also denies:\n{}",
                rep.render_human()
            );
        }
        checked += 1;
    }
    assert!(checked >= 15, "expected the full corpus, found {checked} fixtures");
}

#[test]
fn shipped_presets_check_clean() {
    let cfg = ExperimentConfig::default();
    for name in ["default", "tiny", "deep_cifar", "tiny_deep"] {
        let spec = ArchSpec::preset(name).unwrap();
        let mut rep = analysis::check_spec(&spec);
        rep.merge(analysis::check_plan(
            &spec,
            &cfg.device_profiles(),
            &default_plan_options(&cfg),
        ));
        assert!(!rep.has_deny(), "preset {name}:\n{}", rep.render_human());
    }
}

#[test]
fn shipped_example_configs_check_clean() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/configs");
    let mut checked = 0usize;
    for entry in std::fs::read_dir(&dir).expect("examples/configs must exist") {
        let path = entry.unwrap().path();
        if !path.extension().is_some_and(|e| e == "json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let rep = analysis::check_config_text(&text);
        assert!(!rep.has_deny(), "{}:\n{}", path.display(), rep.render_human());
        checked += 1;
    }
    assert!(checked >= 2, "expected at least smoke + adaptive configs, found {checked}");
}

/// A graph round-tripped through the runtime's own serializer must be
/// analysis-clean, and a clean analysis implies the strict parser accepts
/// the document (the cross-check in `check_graph_json`).
#[test]
fn serialized_specs_are_analysis_clean() {
    for spec in [ArchSpec::tiny(), ArchSpec::native_default(), ArchSpec::deep_cifar()] {
        let rep = analysis::check_graph_text(&spec.to_json());
        assert!(!rep.has_deny(), "{}:\n{}", spec.label(), rep.render_human());
        assert!(
            rep.diags.iter().any(|d| d.code == "G102"),
            "resource totals missing, so the cross-check never parsed the doc"
        );
    }
}

#[test]
fn dead_adaptive_knob_lints_surface_through_the_text_entry_point() {
    let rep = analysis::check_config_text(
        r#"{
            "name": "dead-knobs",
            "trainer": {"steps": 4},
            "adaptive": {"enabled": true, "warmup_steps": 100}
        }"#,
    );
    assert!(rep.diags.iter().any(|d| d.code == "C004"), "{}", rep.render_human());
    assert!(!rep.has_deny(), "{}", rep.render_human());
}

#[test]
fn check_experiment_denies_a_broken_inline_arch() {
    use convdist::config::ArchChoice;
    // The strict config parser rejects a malformed inline graph eagerly, so
    // a hand-assembled struct is the only way this state can exist — and
    // check_experiment must still deny it (C002), never crash.
    let cfg = ExperimentConfig {
        arch: Some(ArchChoice::Graph("{\"layers\": ".into())),
        ..Default::default()
    };
    let rep = analysis::check_experiment(&cfg);
    assert!(rep.diags.iter().any(|d| d.code == "C002"), "{}", rep.render_human());
    assert!(rep.has_deny());

    // A valid preset passes end to end, and the registry/JSONL contract
    // holds for everything it reported.
    let cfg = ExperimentConfig::from_json_str(r#"{"name": "x", "arch": "tiny"}"#).unwrap();
    let rep = analysis::check_experiment(&cfg);
    assert!(!rep.has_deny(), "{}", rep.render_human());
    for d in &rep.diags {
        lookup(d.code).expect("every emitted code is registered");
    }
    let jsonl = rep.render_jsonl();
    assert_eq!(jsonl.lines().count(), rep.diags.len());
    for line in jsonl.lines() {
        convdist::util::json::Json::parse(line).expect("JSONL lines parse");
    }
}
