//! Shared helpers for integration tests.  With the default native backend
//! no artifacts are needed: `Runtime::open` synthesizes the manifest from
//! `ArchSpec::native_default` when `manifest.json` is absent.
//!
//! Each test binary compiles its own copy of this module, and not every
//! binary uses every helper — hence the dead-code allowance.
#![allow(dead_code)]

use std::sync::Arc;

use convdist::runtime::Runtime;

/// Open the repo's artifact directory (native backend needs no artifacts;
/// a checked-in `manifest.json`, if present, pins the architecture).
pub fn runtime() -> Arc<Runtime> {
    let dir = convdist::artifacts_dir();
    Runtime::open(&dir)
        .unwrap_or_else(|e| panic!("opening runtime over {dir:?} failed: {e:#}"))
}

/// Default trainer config for fast tests.
pub fn fast_cfg(steps: usize) -> convdist::config::TrainerConfig {
    convdist::config::TrainerConfig {
        steps,
        lr: 0.03,
        momentum: 0.9,
        weight_decay: 0.0,
        seed: 42,
        log_every: 100,
        calib_rounds: 1,
        checkpoint_every: None,
    }
}
