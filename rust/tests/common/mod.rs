//! Shared helpers for integration tests (require `make artifacts`).

use std::sync::Arc;

use convdist::runtime::Runtime;

/// Open the repo's artifact directory; panics with a actionable message if
/// `make artifacts` has not been run.
pub fn runtime() -> Arc<Runtime> {
    let dir = convdist::artifacts_dir();
    Runtime::open(&dir).unwrap_or_else(|e| {
        panic!("integration tests need artifacts (run `make artifacts`): {e:#}")
    })
}

/// Default trainer config for fast tests.
pub fn fast_cfg(steps: usize) -> convdist::config::TrainerConfig {
    convdist::config::TrainerConfig {
        steps,
        lr: 0.03,
        momentum: 0.9,
        weight_decay: 0.0,
        seed: 42,
        log_every: 100,
        calib_rounds: 1,
    }
}
