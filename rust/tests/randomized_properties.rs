//! Seeded randomized property tests over the coordinator substrates —
//! the offline stand-in for proptest (documented in Cargo.toml).  Each
//! property runs hundreds of random cases from a fixed-seed PCG stream, so
//! failures are reproducible by seed.
//!
//! These tests need no artifacts (pure L3 logic).

use convdist::proto::{frame_len, read_frame, write_frame, Message, WireTensor};
use convdist::sched::{apportion, bottleneck_cost, fit_bucket, partition_layer, workload_shares, Shard};
use convdist::tensor::{Pcg32, Tensor};

const CASES: usize = 300;

fn rand_times(rng: &mut Pcg32, n: usize) -> Vec<f64> {
    (0..n).map(|_| 0.01 + rng.next_f32() as f64 * 10.0).collect()
}

#[test]
fn prop_partition_tiles_layer_exactly() {
    let mut rng = Pcg32::seed(1001);
    for case in 0..CASES {
        let n_dev = 1 + rng.next_below(8) as usize;
        let k = 1 + rng.next_below(200) as usize;
        let times = rand_times(&mut rng, n_dev);
        // Bucket ladder mirroring model.bucket_ladder.
        let buckets: Vec<usize> = (1..=8)
            .map(|i| ((k * i + 7) / 8 + 3) / 4 * 4)
            .map(|b| b.clamp(1, k))
            .collect();
        let shards = partition_layer(k, &times, &buckets)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        let mut prev_hi = 0usize;
        for s in &shards {
            assert_eq!(s.lo, prev_hi, "case {case}: shards must tile contiguously");
            assert!(s.len() > 0 && s.len() <= s.bucket, "case {case}: bucket fit");
            prev_hi = s.hi;
        }
        assert_eq!(prev_hi, k, "case {case}: full coverage");
        // No device appears twice.
        let mut devs: Vec<usize> = shards.iter().map(|s| s.device).collect();
        devs.sort_unstable();
        devs.dedup();
        assert_eq!(devs.len(), shards.len(), "case {case}: duplicate device");
    }
}

#[test]
fn prop_eq1_shares_normalized_and_inverse_to_time() {
    let mut rng = Pcg32::seed(1002);
    for case in 0..CASES {
        let n = 1 + rng.next_below(16) as usize;
        let times = rand_times(&mut rng, n);
        let shares = workload_shares(&times).unwrap();
        let sum: f64 = shares.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "case {case}: shares sum {sum}");
        // Faster device never gets a smaller share.
        for i in 0..n {
            for j in 0..n {
                if times[i] < times[j] {
                    assert!(
                        shares[i] >= shares[j] - 1e-12,
                        "case {case}: t{i}={} < t{j}={} but share {} < {}",
                        times[i],
                        times[j],
                        shares[i],
                        shares[j]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_apportion_exact_and_fair() {
    let mut rng = Pcg32::seed(1003);
    for case in 0..CASES {
        let n = 1 + rng.next_below(12) as usize;
        let k = 1 + rng.next_below(2000) as usize;
        let times = rand_times(&mut rng, n);
        let shares = workload_shares(&times).unwrap();
        let counts = apportion(k, &shares).unwrap();
        assert_eq!(counts.iter().sum::<usize>(), k, "case {case}");
        // Largest-remainder: every count within 1 of the ideal.
        for (c, s) in counts.iter().zip(&shares) {
            let ideal = s * k as f64;
            assert!(
                (*c as f64 - ideal).abs() <= 1.0 + 1e-9,
                "case {case}: count {c} vs ideal {ideal:.3}"
            );
        }
    }
}

#[test]
fn prop_eq1_split_never_worse_than_equal_split() {
    // The paper's whole premise, as an invariant: the Eq. 1 partition's
    // bottleneck cost <= the equal split's bottleneck cost (continuous
    // buckets so padding does not blur the comparison).
    let mut rng = Pcg32::seed(1004);
    for case in 0..CASES {
        let n = 2 + rng.next_below(6) as usize;
        let k = n * (1 + rng.next_below(100) as usize);
        let times = rand_times(&mut rng, n);
        let buckets: Vec<usize> = (1..=k).collect();
        let balanced = partition_layer(k, &times, &buckets).unwrap();
        let per = k / n;
        let naive: Vec<Shard> = (0..n)
            .map(|i| Shard { device: i, lo: i * per, hi: (i + 1) * per, bucket: per })
            .collect();
        let b = bottleneck_cost(&balanced, &times);
        let q = bottleneck_cost(&naive, &times);
        assert!(
            b <= q * 1.0001 + 1e-12,
            "case {case}: balanced {b} worse than equal {q} (times {times:?})"
        );
    }
}

#[test]
fn prop_fit_bucket_minimal() {
    let mut rng = Pcg32::seed(1005);
    for _ in 0..CASES {
        let mut buckets: Vec<usize> = (0..1 + rng.next_below(10) as usize)
            .map(|_| 1 + rng.next_below(512) as usize)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        let n = 1 + rng.next_below(512) as usize;
        match fit_bucket(n, &buckets) {
            Ok(b) => {
                assert!(b >= n);
                assert!(buckets.iter().all(|&x| x < n || x >= b), "not minimal");
            }
            Err(_) => assert!(buckets.iter().all(|&x| x < n)),
        }
    }
}

fn rand_tensor(rng: &mut Pcg32) -> WireTensor {
    let rank = 1 + rng.next_below(4) as usize;
    let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.next_below(6) as usize).collect();
    WireTensor::from(&Tensor::randn(&shape, rng))
}

fn rand_message(rng: &mut Pcg32) -> Message {
    match rng.next_below(12) {
        0 => Message::Hello { worker_id: rng.next_u32(), version: rng.next_u32() },
        1 => Message::Calibrate { rounds: rng.next_u32() },
        2 => Message::CalibrateResult { seconds: rng.next_f32() as f64 },
        3 => Message::ConvWork {
            seq: rng.next_u32(),
            layer: (1 + rng.next_below(2)) as u8,
            dir: rng.next_below(2) as u8,
            bucket: rng.next_below(64),
            inputs: rand_tensor(rng),
            kernels: rand_tensor(rng),
            extra: if rng.next_below(2) == 0 { Some(rand_tensor(rng)) } else { None },
        },
        4 => Message::ConvResult {
            seq: rng.next_u32(),
            outputs: (0..rng.next_below(4)).map(|_| rand_tensor(rng)).collect(),
            seconds: rng.next_f32() as f64,
        },
        5 => Message::AllOk,
        6 => Message::TrainOver,
        7 => Message::Ping { nonce: rng.next_u32() },
        8 => Message::Pong { nonce: rng.next_u32() },
        9 => Message::Leave {
            worker_id: rng.next_u32(),
            reason: format!("l{}", rng.next_u32()),
        },
        10 => Message::ShardUpdate {
            layer: (1 + rng.next_below(2)) as u8,
            lo: rng.next_below(64),
            hi: rng.next_below(64),
            bucket: rng.next_below(64),
        },
        _ => Message::Error { reason: format!("e{}", rng.next_u32()) },
    }
}

#[test]
fn prop_protocol_roundtrips_random_messages() {
    let mut rng = Pcg32::seed(2001);
    for case in 0..CASES {
        let msg = rand_message(&mut rng);
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        assert_eq!(buf.len(), frame_len(&msg), "case {case}: frame_len mismatch");
        let back = read_frame(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(back, msg, "case {case}");
    }
}

#[test]
fn prop_corrupted_frames_error_never_panic() {
    // Flip a random byte (or truncate) in a valid frame: decoding must
    // return Err or an unequal message — never panic, never hang.
    let mut rng = Pcg32::seed(2002);
    for case in 0..CASES {
        let msg = rand_message(&mut rng);
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        if rng.next_below(4) == 0 {
            let cut = 1 + rng.next_below(buf.len() as u32 - 1) as usize;
            buf.truncate(cut);
        } else {
            let pos = rng.next_below(buf.len() as u32) as usize;
            buf[pos] ^= 1 << rng.next_below(8);
        }
        match read_frame(&mut std::io::Cursor::new(buf)) {
            Ok(decoded) => {
                // A flip inside the payload is caught by CRC, so a clean
                // decode can only come from a flip that the CRC re-matches —
                // astronomically unlikely; a flip in the *length/magic/id*
                // fields errors. Accept equal-decodes only.
                assert_eq!(decoded, msg, "case {case}: silent corruption");
            }
            Err(_) => {}
        }
    }
}

#[test]
fn prop_tensor_slice_concat_inverse() {
    let mut rng = Pcg32::seed(2003);
    for case in 0..CASES {
        let b = 1 + rng.next_below(4) as usize;
        let k = 2 + rng.next_below(24) as usize;
        let h = 1 + rng.next_below(6) as usize;
        let t = Tensor::randn(&[b, k, h, h], &mut rng);
        // Random partition of the k axis.
        let mut cuts: Vec<usize> = (0..rng.next_below(3)).map(|_| 1 + rng.next_below(k as u32 - 1) as usize).collect();
        cuts.push(0);
        cuts.push(k);
        cuts.sort_unstable();
        cuts.dedup();
        let parts: Vec<Tensor> = cuts
            .windows(2)
            .map(|w| t.slice_axis1(w[0], w[1]).unwrap())
            .collect();
        let back = Tensor::concat_axis1(&parts).unwrap();
        assert_eq!(back, t, "case {case}");
    }
}

#[test]
fn prop_json_parser_never_panics_on_mutations() {
    use convdist::util::json::Json;
    let seed_doc = r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": true, "d": null}"#;
    let mut rng = Pcg32::seed(2004);
    for _ in 0..CASES {
        let mut bytes = seed_doc.as_bytes().to_vec();
        for _ in 0..1 + rng.next_below(4) {
            let pos = rng.next_below(bytes.len() as u32) as usize;
            bytes[pos] = (rng.next_below(94) + 32) as u8;
        }
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s); // must not panic
        }
    }
}
