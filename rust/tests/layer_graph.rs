//! The layer-graph API, end to end: a 3-conv architecture — inexpressible
//! under the old two-conv `ArchSpec` — must train through the *distributed*
//! master/worker path with per-layer Eq. 1 partitioning and match
//! single-device training, and its fused `grad_full` gradients must pass an
//! e2e directional finite-difference check on the native backend.

use std::sync::Arc;

use convdist::baselines::SingleDeviceTrainer;
use convdist::cluster::{worker_loop, WorkerOptions};
use convdist::config::TrainerConfig;
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::model::Params;
use convdist::net::{inproc_pair, Link};
use convdist::runtime::{ArchSpec, Runtime};
use convdist::session::SessionBuilder;
use convdist::tensor::Value;

fn deep_runtime() -> Arc<Runtime> {
    Runtime::for_arch(ArchSpec::tiny_deep())
}

fn cfg(steps: usize) -> TrainerConfig {
    TrainerConfig {
        steps,
        lr: 0.05,
        momentum: 0.9,
        weight_decay: 0.0,
        seed: 42,
        log_every: 1000,
        calib_rounds: 1,
        checkpoint_every: None,
    }
}

/// A worker thread over an in-proc link with its own tiny_deep runtime
/// (one runtime per device, like the TCP deployment).
fn spawn_deep_worker(id: u32, throttle: Throttle) -> Box<dyn Link> {
    let (master_end, worker_end) = inproc_pair();
    std::thread::Builder::new()
        .name(format!("deep-worker-{id}"))
        .spawn(move || {
            let rt = Runtime::for_arch(ArchSpec::tiny_deep());
            let _ = worker_loop(worker_end, rt, WorkerOptions::new(id, throttle));
        })
        .expect("spawning deep worker");
    Box::new(master_end)
}

#[test]
fn three_conv_distributed_heterogeneous_matches_single_device() {
    let rt = deep_runtime();
    let arch = rt.arch().clone();
    assert_eq!(arch.num_convs(), 3, "the preset must exercise a third conv layer");
    let cfg = cfg(3);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 5);

    // 2 heterogeneous workers: native speed and 3x slower.
    let links: Vec<Box<dyn Link>> = vec![
        spawn_deep_worker(1, Throttle::none()),
        spawn_deep_worker(2, Throttle::new(3.0)),
    ];
    let mut dist = SessionBuilder::new()
        .arch_spec(ArchSpec::tiny_deep())
        .trainer(cfg.clone())
        .links(links)
        .build()
        .unwrap();
    let mut single = SingleDeviceTrainer::new(rt.clone(), &cfg, Throttle::none()).unwrap();

    // Every conv layer got its own Eq. 1 shard table covering [0, k).
    for layer in 1..=arch.num_convs() {
        let covered: usize = dist.trainer().shards(layer).iter().map(|s| s.len()).sum();
        assert_eq!(covered, arch.kernels(layer), "conv{layer} not fully covered");
    }

    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let r = dist.step(&batch).unwrap();
        assert_eq!(r.devices, 3);
        let (sl, _) = single.step(&batch).unwrap();
        assert!(
            (r.loss - sl).abs() <= 1e-4 * sl.abs().max(1.0),
            "step {step}: distributed loss {} vs single {sl}",
            r.loss
        );
    }
    let diff = dist.trainer().params.max_abs_diff(&single.params).unwrap();
    assert!(diff <= 1e-4, "3-conv distributed vs single params diverged: {diff}");

    // The eval path composes over three conv layers too.
    let held_out = ds.batch(arch.batch, 999).unwrap();
    let acc = dist.eval(&held_out).unwrap();
    assert!((0.0..=1.0).contains(&acc));

    dist.shutdown().unwrap();
}

/// Run `grad_full_b{B}` and return `(loss, grads-in-param-order)`.
fn grad_full(
    rt: &Runtime,
    params: &Params,
    images: &convdist::tensor::Tensor,
    labels: &convdist::tensor::ITensor,
) -> (f32, Vec<convdist::tensor::Tensor>) {
    let name = format!("grad_full_b{}", labels.len());
    let mut args = vec![Value::F32(images.clone()), Value::I32(labels.clone())];
    args.extend(params.in_order().into_iter().map(Value::F32));
    let outs = rt.execute(&name, &args).unwrap();
    let mut it = outs.into_iter();
    let loss = it.next().unwrap().as_f32().unwrap().item().unwrap();
    let grads = it.map(|v| v.as_f32().unwrap().clone()).collect();
    (loss, grads)
}

#[test]
fn three_conv_grad_full_passes_directional_gradcheck() {
    // e2e finite differences on the f32 loss are noisy coordinate-wise, so
    // check the *directional* derivative along each parameter's analytic
    // gradient: d/dε L(θ + ε·ĝ) must equal ||g||.  This exercises every
    // kernel in the 3-conv chain (conv, LRN, ReLU, pool, FC, softmax) plus
    // the graph interpreter's fused forward/backward.
    let rt = deep_runtime();
    let arch = rt.arch().clone();
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 7);
    let batch = ds.batch(arch.batch, 0).unwrap();

    let params = Params::init(&arch, 11).unwrap();
    let (_, grads) = grad_full(&rt, &params, &batch.images, &batch.labels);
    assert_eq!(grads.len(), params.names().len());

    let eps = 1e-2f32;
    for (name, g) in params.names().to_vec().into_iter().zip(&grads) {
        let norm = g.l2norm();
        assert!(norm.is_finite(), "grad {name} must be finite");
        if norm < 1e-5 {
            continue; // direction undefined; nothing to check
        }
        let loss_at = |sign: f32| -> f32 {
            let mut p = params.clone();
            let t = p.get_mut(&name).unwrap();
            for (pv, gv) in t.data_mut().iter_mut().zip(g.data()) {
                *pv += sign * eps * gv / norm;
            }
            grad_full(&rt, &p, &batch.images, &batch.labels).0
        };
        let fd = (loss_at(1.0) - loss_at(-1.0)) / (2.0 * eps);
        assert!(
            (fd - norm).abs() <= 5e-2 * norm + 1e-3,
            "param {name}: directional fd {fd} vs ||g|| {norm}"
        );
    }
}

#[test]
fn python_emitted_graph_config_loads_via_manifest() {
    // The cross-language contract: python's `model.graph_config` emitted
    // this fixture (tests/fixtures/py_graph_config.json, asserted
    // byte-identical by python/tests/test_manifest_schema.py); it must load
    // through ArchSpec/Manifest and derive the same architecture the native
    // backend synthesizes for the default 16:32 @ 64 geometry.
    let text = include_str!("fixtures/py_graph_config.json");
    let arch = ArchSpec::from_json_str(text).unwrap();
    let native = ArchSpec::native_default();
    assert_eq!(arch.layers, native.layers);
    assert_eq!(arch.convs, native.convs);
    assert_eq!(arch.param_shapes, native.param_shapes);
    assert_eq!(arch.param_order, native.param_order);
    assert_eq!(arch.batch_buckets, native.batch_buckets);
    assert_eq!(arch.label(), "16:32");
    // The python pipeline pins its own (bigger) probe; the override wins
    // over the synthesized default.
    assert_eq!(arch.probe.flops, 60_211_200);
    assert_eq!((arch.probe.batch, arch.probe.img, arch.probe.k), (16, 32, 32));
    assert_eq!((arch.probe.kh, arch.probe.kw), (5, 5));
    // A full manifest wrapping this config parses end to end.
    let doc = format!("{{\"version\": 1, \"config\": {text}, \"executables\": {{}}}}");
    let m = convdist::runtime::Manifest::from_json_str(&doc, std::path::Path::new("/tmp"))
        .unwrap();
    assert_eq!(m.config.label(), "16:32");
    assert_eq!(m.config.fc_in, 32 * 5 * 5);
}

#[test]
fn deep_preset_opens_workloads_the_old_api_could_not() {
    // The 3-conv deep_cifar preset resolves, enumerates layer-3
    // executables, and its geometry matches the documented spatial chain.
    let arch = ArchSpec::preset("deep_cifar").expect("preset must exist");
    assert_eq!(arch.num_convs(), 3);
    assert_eq!(arch.label(), "32:48:64");
    assert_eq!(arch.fc_in, 256);
    let rt = Runtime::for_arch(arch);
    assert!(rt.manifest().spec("conv3_fwd_b64").is_ok());
    assert!(rt.manifest().spec("mid3_bwd").is_ok());
    assert!(rt.manifest().spec("conv4_fwd_b4").is_err());
}
