//! End-to-end observability contract: a traced adaptive run with a mid-run
//! degradation, a scripted departure and periodic auto-checkpoints must
//! produce a schema-valid `run.jsonl` whose lifecycle/step/event lines sit
//! in causal order, and a `trace.json` that is well-formed Chrome
//! trace-event JSON whose per-step phase spans agree with the step lines'
//! own Comm/Conv/Comp attribution.

use std::path::PathBuf;
use std::time::Duration;

use convdist::cluster::{worker_loop, WorkerOptions};
use convdist::config::TrainerConfig;
use convdist::devices::{Throttle, ThrottlePlan};
use convdist::net::{inproc_pair, Link};
use convdist::obs::{compare, live, runlog, HealthState, ObsConfig, PHASES_TID};
use convdist::proto::Message;
use convdist::runtime::{ArchSpec, Runtime};
use convdist::sched::AdaptiveConfig;
use convdist::session::SessionBuilder;
use convdist::util::json::Json;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("convdist_obs_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A library worker over an in-proc link with span shipping on, optionally
/// carrying a throttle plan (mid-run degradation) and a scripted departure.
fn spawn_traced_worker(id: u32, plan: ThrottlePlan, leave_after: Option<u64>) -> Box<dyn Link> {
    let (master_end, worker_end) = inproc_pair();
    std::thread::Builder::new()
        .name(format!("obs-worker-{id}"))
        .spawn(move || {
            let rt = Runtime::open(convdist::artifacts_dir()).unwrap();
            let mut opts = WorkerOptions::with_plan(id, plan).traced(true);
            opts.leave_after = leave_after;
            let _ = worker_loop(worker_end, rt, opts);
        })
        .unwrap();
    Box::new(master_end)
}

/// The headline scenario from the issue: an adaptive throttled fleet where
/// one worker degrades 8x (forcing a re-shard) and another departs late,
/// with `checkpoint_every` firing twice — every resulting run-log line must
/// validate, and the step/repartition/worker_left/checkpoint/eval lines must
/// land in causal order.
#[test]
fn traced_adaptive_run_logs_events_in_causal_order() {
    let trace_dir = tmpdir("causal");
    let ckpt_dir = tmpdir("causal_ckpt");
    let steps = 12usize;

    let fast = Throttle::virtual_gflops(2.0);
    let slow = Throttle::virtual_gflops(0.25); // 8x degradation
    // Worker 1 (device 1) degrades after 3 steps (4 conv frames per step);
    // worker 2 (device 2) leaves during step 10 (after 36 frames).
    let links: Vec<Box<dyn Link>> = vec![
        spawn_traced_worker(1, ThrottlePlan::degrade_after(fast, 12, slow), None),
        spawn_traced_worker(2, ThrottlePlan::fixed(fast), Some(36)),
    ];
    let adaptive = AdaptiveConfig {
        alpha: 0.5,
        warmup_steps: 1,
        imbalance_threshold: 0.2,
        hysteresis: 0.05,
        cooldown_steps: 2,
        heartbeat_every: 0,
        ..Default::default()
    };
    let cfg = TrainerConfig {
        steps,
        calib_rounds: 1,
        log_every: 100,
        checkpoint_every: Some(5),
        ..Default::default()
    };
    let mut session = SessionBuilder::new()
        .trainer(cfg)
        .master_throttle(fast)
        .links(links)
        .adaptive(adaptive)
        .observe(ObsConfig::trace_to(&trace_dir))
        .checkpoint_dir(&ckpt_dir)
        .build()
        .unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.steps_run, steps);
    assert!(report.repartitions >= 1, "degradation never re-sharded");
    assert_eq!(report.departures, 1, "scripted departure never landed");
    let table = session.finish_obs().unwrap().expect("--trace implies metrics");
    assert!(table.contains("steps"), "{table}");
    assert!(table.contains("sched.repartitions"), "{table}");
    assert!(table.contains("net.dev1.bytes"), "{table}");
    session.shutdown().unwrap();
    assert!(ckpt_dir.join("step5.ckpt").exists());
    assert!(ckpt_dir.join("step10.ckpt").exists());

    // Every line validates; the validator is the single schema authority.
    let text = std::fs::read_to_string(trace_dir.join("run.jsonl")).unwrap();
    let lines = runlog::validate_text(&text).unwrap();
    let ty = |v: &Json| v.get("type").unwrap().as_str().unwrap().to_string();
    assert_eq!(ty(&lines[0]), "run_start");
    assert_eq!(ty(lines.last().unwrap()), "run_end");
    assert_eq!(lines[0].get("devices").unwrap().as_u64().unwrap(), 3);
    assert_eq!(lines[0].get("steps").unwrap().as_u64().unwrap(), steps as u64);

    // Causal order: step lines strictly increasing; every repartition /
    // worker_left / checkpoint line refers to the most recent step line
    // (the session emits them right after the step they happened in).
    let mut last_step = 0u64;
    let mut counts = std::collections::BTreeMap::new();
    for v in &lines {
        let t = ty(v);
        *counts.entry(t.clone()).or_insert(0u64) += 1;
        match t.as_str() {
            "step" => {
                let s = v.get("step").unwrap().as_u64().unwrap();
                assert_eq!(s, last_step + 1, "step lines must be consecutive");
                last_step = s;
            }
            "repartition" | "worker_left" | "checkpoint" => {
                let s = v.get("step").unwrap().as_u64().unwrap();
                assert_eq!(s, last_step, "{t} line out of causal position");
            }
            "eval" => {
                assert_eq!(last_step, steps as u64, "eval must come after the last step");
            }
            _ => {}
        }
    }
    assert_eq!(counts["step"], steps as u64);
    assert_eq!(counts["eval"], 1);
    assert_eq!(counts["worker_left"], 1);
    assert_eq!(counts["checkpoint"], 2);
    assert!(counts["repartition"] >= 1);
    assert_eq!(counts["metrics"], 1);
    assert!(counts["span"] > 0, "a traced run must record spans");

    // Worker-side spans crossed the wire: conv spans on worker device rows
    // and their serve (comm) envelopes, re-anchored into the master's log.
    let span_on = |device: u64, cat: &str| {
        lines.iter().any(|v| {
            ty(v) == "span"
                && v.get("device").unwrap().as_u64().unwrap() == device
                && v.get("cat").unwrap().as_str().unwrap() == cat
        })
    };
    assert!(span_on(1, "conv"), "worker 1 conv spans missing");
    assert!(span_on(2, "conv"), "worker 2 conv spans missing");
    assert!(span_on(1, "comm"), "worker 1 serve spans missing");
    assert!(span_on(0, "conv"), "master-shard conv spans missing");
    assert!(span_on(0, "step"), "step spans missing");

    let _ = std::fs::remove_dir_all(&trace_dir);
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

/// Trace-export golden contract on the tiny preset: `trace.json` is valid
/// Chrome trace-event JSON (named rows, complete "X" events), and for every
/// step the phase spans on the [`PHASES_TID`] row sum to the step line's own
/// `comm_us`/`conv_us`/`comp_us` within 5% — the acceptance bound between
/// the trace and the printed `Breakdown`.
#[test]
fn trace_json_is_valid_and_phase_spans_match_step_breakdowns() {
    let trace_dir = tmpdir("trace");
    let v = Throttle::virtual_gflops(0.2);
    let cfg = TrainerConfig { steps: 3, calib_rounds: 1, log_every: 100, ..Default::default() };
    let mut session = SessionBuilder::new()
        .arch_spec(ArchSpec::tiny())
        .trainer(cfg)
        .master_throttle(v)
        .workers(&[v, v])
        .observe(ObsConfig::trace_to(&trace_dir))
        .build()
        .unwrap();
    session.run().unwrap();
    session.shutdown().unwrap();

    // Per-step phase totals from the run log's step lines.
    let text = std::fs::read_to_string(trace_dir.join("run.jsonl")).unwrap();
    let lines = runlog::validate_text(&text).unwrap();
    let mut want: Vec<(u64, [f64; 3])> = Vec::new();
    for v in &lines {
        if v.get("type").unwrap().as_str().unwrap() == "step" {
            want.push((
                v.get("step").unwrap().as_u64().unwrap(),
                [
                    v.get("comm_us").unwrap().as_f64().unwrap(),
                    v.get("conv_us").unwrap().as_f64().unwrap(),
                    v.get("comp_us").unwrap().as_f64().unwrap(),
                ],
            ));
        }
    }
    assert_eq!(want.len(), 3);

    // The trace parses; rows are named; X events carry ts/dur/args.
    let trace = std::fs::read_to_string(trace_dir.join("trace.json")).unwrap();
    let doc = Json::parse(&trace).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut row_names = Vec::new();
    let mut phase_sums: std::collections::BTreeMap<(u64, String), f64> =
        std::collections::BTreeMap::new();
    for e in events {
        match e.get("ph").unwrap().as_str().unwrap() {
            "M" => {
                if e.get("name").unwrap().as_str().unwrap() == "thread_name" {
                    let name = e.get("args").unwrap().get("name").unwrap().as_str().unwrap();
                    row_names.push(name.to_string());
                }
            }
            "X" => {
                let tid = e.get("tid").unwrap().as_u64().unwrap();
                e.get("ts").unwrap().as_u64().unwrap();
                let dur = e.get("dur").unwrap().as_u64().unwrap();
                let step = e.get("args").unwrap().get("step").unwrap().as_u64().unwrap();
                if tid == PHASES_TID as u64 {
                    let cat = e.get("cat").unwrap().as_str().unwrap().to_string();
                    *phase_sums.entry((step, cat)).or_insert(0.0) += dur as f64;
                }
            }
            other => panic!("unexpected trace event ph {other:?}"),
        }
    }
    assert!(row_names.iter().any(|n| n.contains("master")), "{row_names:?}");
    assert!(row_names.iter().any(|n| n.contains("device 2")), "{row_names:?}");
    assert!(row_names.iter().any(|n| n.contains("phases")), "{row_names:?}");

    // Fig. 6 agreement: trace phase spans vs the step lines, within 5%.
    for (step, [comm, conv, comp]) in want {
        for (cat, us) in [("comm", comm), ("conv", conv), ("comp", comp)] {
            let got = phase_sums.get(&(step, cat.to_string())).copied().unwrap_or(0.0);
            assert!(
                (got - us).abs() <= 0.05 * us + 2.0,
                "step {step} phase {cat}: trace {got}us vs run log {us}us"
            );
        }
    }

    let _ = std::fs::remove_dir_all(&trace_dir);
}

/// A worker that serves calibration and `live` ConvWork frames, then wedges
/// — keeps the link open but never replies again (the silent-straggler
/// harness from the adaptive-sched suite).
fn spawn_wedging_worker(id: u32, live: usize) -> Box<dyn Link> {
    let (master_end, mut worker_end) = inproc_pair();
    std::thread::spawn(move || {
        let rt = Runtime::open(convdist::artifacts_dir()).unwrap();
        worker_end.send(&Message::Hello { worker_id: id, version: 1 }).unwrap();
        let mut served = 0usize;
        loop {
            match worker_end.recv() {
                Ok(Message::Calibrate { .. }) => {
                    worker_end.send(&Message::CalibrateResult { seconds: 0.01 }).unwrap();
                }
                Ok(Message::ConvWork { seq, layer, dir, bucket, inputs, kernels, extra }) => {
                    if served >= live {
                        loop {
                            std::thread::sleep(Duration::from_secs(3600));
                        }
                    }
                    served += 1;
                    let reply = convdist::cluster::compute_conv_work(
                        &rt,
                        Throttle::none(),
                        seq,
                        layer,
                        dir,
                        bucket as usize,
                        inputs,
                        kernels,
                        extra,
                    )
                    .unwrap();
                    worker_end.send(&reply).unwrap();
                }
                Ok(Message::AllOk) | Ok(Message::ShardUpdate { .. }) => {}
                Ok(Message::TrainOver) | Err(_) => return,
                Ok(other) => panic!("unexpected {other:?}"),
            }
        }
    });
    Box::new(master_end)
}

/// The health ladder end to end: a worker degrading 8x mid-run must walk
/// Healthy -> Degraded -> Straggling (never skipping a rung), and every
/// `health` run-log line must trail the step line it belongs to.
#[test]
fn degrading_worker_walks_the_health_ladder_in_causal_order() {
    let trace_dir = tmpdir("ladder");
    let steps = 10usize;
    let fast = Throttle::virtual_gflops(2.0);
    let slow = Throttle::virtual_gflops(0.25); // 8x degradation
    let links: Vec<Box<dyn Link>> = vec![
        spawn_traced_worker(1, ThrottlePlan::degrade_after(fast, 8, slow), None),
        spawn_traced_worker(2, ThrottlePlan::fixed(fast), None),
    ];
    let adaptive = AdaptiveConfig {
        alpha: 0.5,
        warmup_steps: 1,
        imbalance_threshold: 0.2,
        hysteresis: 0.05,
        cooldown_steps: 2,
        heartbeat_every: 0,
        ..Default::default()
    };
    let cfg = TrainerConfig { steps, calib_rounds: 1, log_every: 100, ..Default::default() };
    let mut session = SessionBuilder::new()
        .trainer(cfg)
        .master_throttle(fast)
        .links(links)
        .adaptive(adaptive)
        .observe(ObsConfig::trace_to(&trace_dir))
        .build()
        .unwrap();
    session.run().unwrap();
    assert_eq!(
        session.trainer().health_states()[1],
        HealthState::Straggling,
        "8x straggler must end Straggling: {:?}",
        session.trainer().health_states()
    );
    session.shutdown().unwrap();

    let text = std::fs::read_to_string(trace_dir.join("run.jsonl")).unwrap();
    let lines = runlog::validate_text(&text).unwrap();
    let mut last_step = 0u64;
    let mut ladder: Vec<(String, String)> = Vec::new();
    for v in &lines {
        match v.get("type").unwrap().as_str().unwrap() {
            "step" => last_step = v.get("step").unwrap().as_u64().unwrap(),
            "health" => {
                assert_eq!(
                    v.get("step").unwrap().as_u64().unwrap(),
                    last_step,
                    "health line out of causal position"
                );
                if v.get("device").unwrap().as_u64().unwrap() == 1 {
                    ladder.push((
                        v.get("from").unwrap().as_str().unwrap().to_string(),
                        v.get("to").unwrap().as_str().unwrap().to_string(),
                    ));
                }
            }
            _ => {}
        }
    }
    assert!(ladder.len() >= 2, "degradation produced too few transitions: {ladder:?}");
    assert_eq!(ladder[0], ("healthy".to_string(), "degraded".to_string()), "{ladder:?}");
    assert_eq!(ladder[1], ("degraded".to_string(), "straggling".to_string()), "{ladder:?}");
    // Transition chain is contiguous: each from equals the previous to.
    for w in ladder.windows(2) {
        assert_eq!(w[0].1, w[1].0, "ladder skipped a rung: {ladder:?}");
    }

    let _ = std::fs::remove_dir_all(&trace_dir);
}

/// A silently wedged worker blows the gather deadline, is dropped, and the
/// run log shows it: `worker_left`, then the `health` line to `lost` — in
/// that order, both attributed to the step the drop happened in.
#[test]
fn hung_worker_is_reported_lost_after_the_gather_drop() {
    let trace_dir = tmpdir("lost");
    let links: Vec<Box<dyn Link>> = vec![
        spawn_wedging_worker(1, 4),
        spawn_traced_worker(2, ThrottlePlan::fixed(Throttle::none()), None),
    ];
    let adaptive = AdaptiveConfig {
        gather_timeout: Some(Duration::from_millis(500)),
        heartbeat_every: 0,
        ..Default::default()
    };
    let cfg = TrainerConfig { steps: 3, calib_rounds: 1, log_every: 100, ..Default::default() };
    let mut session = SessionBuilder::new()
        .trainer(cfg)
        .links(links)
        .adaptive(adaptive)
        .observe(ObsConfig::trace_to(&trace_dir))
        .build()
        .unwrap();
    session.run().unwrap();
    assert_eq!(session.trainer().health_states()[1], HealthState::Lost);
    let table = session.finish_obs().unwrap().expect("--trace implies metrics");
    assert!(table.contains("health.dev1"), "{table}");
    session.shutdown().unwrap();

    let text = std::fs::read_to_string(trace_dir.join("run.jsonl")).unwrap();
    let lines = runlog::validate_text(&text).unwrap();
    let pos = |ty_want: &str, extra: fn(&Json) -> bool| {
        lines
            .iter()
            .position(|v| v.get("type").unwrap().as_str().unwrap() == ty_want && extra(v))
    };
    let left = pos("worker_left", |_| true).expect("no worker_left line");
    let lost = pos("health", |v| {
        v.get("device").unwrap().as_u64().unwrap() == 1
            && v.get("to").unwrap().as_str().unwrap() == "lost"
    })
    .expect("no health->lost line");
    assert!(lost > left, "lost health line must trail the worker_left line");
    assert_eq!(
        lines[left].get("step").unwrap().as_u64().unwrap(),
        lines[lost].get("step").unwrap().as_u64().unwrap(),
        "drop and its health transition must share a step"
    );

    let _ = std::fs::remove_dir_all(&trace_dir);
}

/// The live tier end to end: a session serving `--metrics-addr` exposes
/// parseable Prometheus text with per-device health while running, the
/// `top` snapshot renders the degraded worker, and the endpoint goes away
/// with `finish_obs`.
#[test]
fn live_endpoint_serves_health_and_top_renders_it() {
    let steps = 6usize;
    let fast = Throttle::virtual_gflops(2.0);
    let slow = Throttle::virtual_gflops(0.25);
    let links: Vec<Box<dyn Link>> = vec![
        spawn_traced_worker(1, ThrottlePlan::degrade_after(fast, 8, slow), None),
        spawn_traced_worker(2, ThrottlePlan::fixed(fast), None),
    ];
    let adaptive = AdaptiveConfig {
        alpha: 0.5,
        warmup_steps: 1,
        imbalance_threshold: 0.2,
        hysteresis: 0.05,
        cooldown_steps: 2,
        heartbeat_every: 0,
        ..Default::default()
    };
    let cfg = TrainerConfig { steps, calib_rounds: 1, log_every: 100, ..Default::default() };
    let mut session = SessionBuilder::new()
        .trainer(cfg)
        .master_throttle(fast)
        .links(links)
        .adaptive(adaptive)
        .observe(ObsConfig::metrics_only().serve("127.0.0.1:0"))
        .build()
        .unwrap();
    let addr = session.metrics_addr().expect("serve() must bind an endpoint").to_string();

    session.run().unwrap();

    // Scrape while the session is still up (the endpoint lives until
    // finish_obs/shutdown).
    let body = live::http_get(&addr).unwrap();
    assert!(body.contains("convdist_up 1"), "{body}");
    assert!(body.contains("# TYPE convdist_steps counter"), "{body}");
    let snap = live::TopSnapshot::from_prometheus(&body).unwrap();
    assert_eq!(snap.steps, steps as u64);
    assert_eq!(snap.devices.len(), 3, "{snap:?}");
    assert_eq!(snap.devices[1].health, HealthState::Straggling, "{snap:?}");
    assert_eq!(snap.devices[2].health, HealthState::Healthy, "{snap:?}");
    assert!(snap.devices[1].share.is_some(), "share gauges must be live: {snap:?}");
    let table = snap.render();
    assert!(table.contains("straggling"), "{table}");

    session.finish_obs().unwrap();
    assert!(session.metrics_addr().is_none(), "finish_obs must stop the endpoint");
    assert!(live::http_get(&addr).is_err(), "endpoint must stop serving");
    session.shutdown().unwrap();
}

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// The committed golden baseline vs itself is clean; vs the 1.5x-slowed
/// variant (a >= 20% injected slowdown) the gate trips — the exact pair CI
/// runs through `convdist compare`.
#[test]
fn compare_gate_detects_slowdown_between_fixtures() {
    let golden = compare::stats_from_file(&fixture("golden_run.jsonl")).unwrap();
    let slow = compare::stats_from_file(&fixture("golden_run_slow.jsonl")).unwrap();
    assert_eq!(golden.steps, 10);
    assert_eq!((golden.repartitions, golden.departures, golden.anomalies), (1, 1, 1));

    let self_rep = compare::compare(&golden, &golden, 10.0);
    assert!(!self_rep.regressed(), "{}", self_rep.render_human(10, 10));

    let rep = compare::compare(&golden, &slow, 10.0);
    assert!(rep.regressed(), "{}", rep.render_human(10, 10));
    let p50 = rep.deltas.iter().find(|d| d.metric == "step_p50_ms").unwrap();
    assert!((p50.pct - 50.0).abs() < 1.0, "expected ~50% step slowdown, got {}", p50.pct);

    // An improvement (slow baseline, fast candidate) never trips.
    assert!(!compare::compare(&slow, &golden, 10.0).regressed());
}

/// Interior corruption is a hard error with its 1-based line number — for
/// the strict validator, the lenient tail reader, `top` and `compare` alike.
#[test]
fn corrupt_fixture_fails_with_its_line_number() {
    let text = std::fs::read_to_string(fixture("corrupt_run.jsonl")).unwrap();
    for err in [
        runlog::validate_text(&text).unwrap_err().to_string(),
        runlog::read_text_tail(&text).unwrap_err().to_string(),
        live::TopSnapshot::from_runlog(&text).unwrap_err().to_string(),
        compare::stats_from_text(&text).unwrap_err().to_string(),
    ] {
        assert!(err.contains("line 3"), "error must name line 3: {err}");
    }
}

/// The `convdist report` path over a real traced run: `summarize_file`
/// validates every line and renders the Figure-6-style table.
#[test]
fn report_summarizes_a_real_traced_run() {
    let trace_dir = tmpdir("report");
    let v = Throttle::virtual_gflops(0.2);
    let cfg = TrainerConfig { steps: 2, calib_rounds: 1, log_every: 100, ..Default::default() };
    let mut session = SessionBuilder::new()
        .arch_spec(ArchSpec::tiny())
        .trainer(cfg)
        .master_throttle(v)
        .workers(&[v])
        .observe(ObsConfig::trace_to(&trace_dir))
        .build()
        .unwrap();
    session.run().unwrap();
    session.shutdown().unwrap();

    let out = convdist::obs::report::summarize_file(&trace_dir.join("run.jsonl")).unwrap();
    assert!(out.contains("2 devices, 2/2 steps"), "{out}");
    assert!(out.contains("phase totals"), "{out}");
    assert!(out.contains("eval accuracy"), "{out}");

    let _ = std::fs::remove_dir_all(&trace_dir);
}
