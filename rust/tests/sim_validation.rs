//! Cross-validation of the analytic simulator against the *real* throttled
//! cluster — the step DESIGN.md promises: the simulator extrapolates to 32
//! nodes (Figures 9-13), so at small scale it must agree with reality on
//! (a) Eq. 1 shard proportions, (b) the conv-time ratio between cluster
//! sizes, and (c) wire volume vs the Eq. 2 + backward model.

mod common;

use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::session::SessionBuilder;
use convdist::sim::ArchShape;

fn arch_shape(rt: &convdist::runtime::Runtime) -> ArchShape {
    // The analytic ArchShape models the paper's two-conv instance; the
    // default runtime arch is exactly that graph.
    let a = rt.arch();
    assert_eq!(a.num_convs(), 2, "ArchShape models the 2-conv paper network");
    let (kh, kw) = a.conv_kernel(1);
    ArchShape {
        k1: a.kernels(1),
        k2: a.kernels(2),
        batch: a.batch,
        img: a.img,
        in_ch: a.in_ch,
        kh,
        kw,
    }
}

#[test]
fn real_wire_volume_matches_eq2_model() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(1);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 41);

    let mut dist = SessionBuilder::new()
        .trainer(cfg.clone())
        .workers(&[Throttle::none(); 2])
        .build()
        .unwrap();
    let batch = ds.batch(arch.batch, 0).unwrap();
    let res = dist.step(&batch).unwrap();

    // Model: same slave share as the actual partition.
    let shape = arch_shape(&rt);
    let slave_share = {
        let mut total = 0.0;
        for layer in [1usize, 2] {
            let k = arch.kernels(layer) as f64;
            let slaves: usize = dist
                .trainer()
                .shards(layer)
                .iter()
                .filter(|s| s.device != 0)
                .map(|s| s.len())
                .sum();
            total += slaves as f64 / k / 2.0;
        }
        total
    };
    let elements = shape.eq2_upload_elements(2, slave_share) + shape.bwd_upload_elements(2, slave_share);
    let model_bytes = elements * 4.0;
    let real = res.bytes_moved as f64;
    // Real frames add headers, shape prefixes and bucket padding; the model
    // must land within 25% of the measured volume.
    let ratio = real / model_bytes;
    assert!(
        (0.75..=1.35).contains(&ratio),
        "Eq.2+bwd model {model_bytes:.0}B vs real wire {real:.0}B (ratio {ratio:.3})"
    );
    dist.shutdown().unwrap();
}

#[test]
fn throttled_cluster_overlaps_conv_like_the_model() {
    // This container has ONE core, so real compute cannot speed up in wall
    // clock; heterogeneity is emulated by VIRTUAL-TIME throttling
    // (devices::Throttle::Virtual): each executable call costs
    // flops/virtual_gflops, and those deterministic sleeps DO overlap across
    // workers.  The cluster must therefore show the simulator's defining
    // behaviour: the conv phase equals the slowest device's shard time, not
    // the sum — i.e. duo conv << solo conv.
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let mut cfg = common::fast_cfg(2);
    cfg.calib_rounds = 1;
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 42);
    let batch = ds.batch(arch.batch, 0).unwrap();

    // 0.5 virtual GFLOPS: conv2_fwd_b64 ≈ 0.65e9 flops ≈ 1.3 virtual
    // seconds, far above its ~40ms real cost even under contention.
    let th = Throttle::virtual_gflops(0.5);

    // Solo master at 10x.
    let mut solo =
        SessionBuilder::new().trainer(cfg.clone()).master_throttle(th).build().unwrap();
    let _ = solo.step(&batch).unwrap(); // warm the executables
    let solo_conv = solo.step(&batch).unwrap().breakdown.conv;

    // Master + 1 worker, both 10x: Eq. 1 splits ~evenly, sleeps overlap.
    let mut duo = SessionBuilder::new()
        .trainer(cfg.clone())
        .master_throttle(th)
        .workers(&[th])
        .build()
        .unwrap();
    let _ = duo.step(&batch).unwrap();
    let duo_conv = duo.step(&batch).unwrap().breakdown.conv;

    let ratio = duo_conv.as_secs_f64() / solo_conv.as_secs_f64();
    assert!(
        ratio < 0.9,
        "2-device conv phase should overlap: duo {duo_conv:?} vs solo {solo_conv:?} (ratio {ratio:.2})"
    );
    // And it cannot beat the ideal halving by much (per-call overhead and
    // bucket padding only make it worse, never better).
    assert!(ratio > 0.35, "suspiciously superlinear overlap: {ratio:.2}");

    solo.shutdown().unwrap();
    duo.shutdown().unwrap();
}

#[test]
fn shard_proportions_match_eq1_shares() {
    // The real calibration + partition must land near the Eq. 1 shares for
    // strongly throttled (deterministic-ish) devices.
    let rt = common::runtime();
    let cfg = common::fast_cfg(1);
    let dist = SessionBuilder::new()
        .trainer(cfg)
        .workers(&[Throttle::new(2.0), Throttle::new(2.0)])
        .build()
        .unwrap();
    // Shares: master 1x, workers 0.5x each -> master = 1/2 of the work.
    let k2 = rt.arch().kernels(2) as f64;
    let master2 = dist
        .trainer()
        .shards(2)
        .iter()
        .find(|s| s.device == 0)
        .map(|s| s.len())
        .unwrap_or(0) as f64;
    let frac = master2 / k2;
    assert!(
        (0.32..=0.68).contains(&frac),
        "master share {frac:.2} should be near 0.5 for a 1x/2x/2x cluster"
    );
    dist.shutdown().unwrap();
}
