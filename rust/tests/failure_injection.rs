//! Fault paths: a worker dying mid-training must not kill the run — the
//! master drops it, re-runs the Eq. 1 partition over the survivors and
//! retries the batch (an extension beyond the paper's protocol; see
//! cluster::master docs).

mod common;

use convdist::cluster::{worker_loop, WorkerOptions};
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::net::{inproc_pair, Link};
use convdist::proto::Message;
use convdist::runtime::Runtime;
use convdist::session::SessionBuilder;

/// A worker that serves calibration + `live_batches` worth of conv work,
/// then drops the link (simulating a crash).
fn spawn_dying_worker(id: u32, live_convworks: usize) -> Box<dyn Link> {
    let (master_end, mut worker_end) = inproc_pair();
    std::thread::spawn(move || {
        let rt = Runtime::open(convdist::artifacts_dir()).unwrap();
        // Minimal inline Algorithm-2 loop so we can die on cue.
        worker_end
            .send(&Message::Hello { worker_id: id, version: 1 })
            .unwrap();
        let mut served = 0usize;
        loop {
            match worker_end.recv() {
                Ok(Message::Calibrate { .. }) => {
                    worker_end.send(&Message::CalibrateResult { seconds: 0.01 }).unwrap();
                }
                Ok(Message::ConvWork { seq, layer, dir, bucket, inputs, kernels, extra }) => {
                    if served >= live_convworks {
                        return; // crash: drop the link without replying
                    }
                    served += 1;
                    // Delegate the real compute to the library worker logic
                    // by round-tripping through a one-shot loop.
                    let reply = convdist::cluster::compute_conv_work(
                        &rt,
                        Throttle::none(),
                        seq,
                        layer,
                        dir,
                        bucket as usize,
                        inputs,
                        kernels,
                        extra,
                    )
                    .unwrap();
                    worker_end.send(&reply).unwrap();
                }
                Ok(Message::AllOk) => {}
                Ok(Message::TrainOver) | Err(_) => return,
                Ok(other) => panic!("unexpected {other:?}"),
            }
        }
    });
    Box::new(master_end)
}

/// A healthy library worker on an in-proc link.
fn spawn_healthy_worker(id: u32) -> Box<dyn Link> {
    let (master_end, worker_end) = inproc_pair();
    std::thread::spawn(move || {
        let rt = Runtime::open(convdist::artifacts_dir()).unwrap();
        let _ = worker_loop(worker_end, rt, WorkerOptions::new(id, Throttle::none()));
    });
    Box::new(master_end)
}

#[test]
fn master_survives_worker_death_and_repartitions() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(3);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 31);

    // Worker 1 dies after serving 2 ConvWork messages (mid-batch: each step
    // issues 4 per worker), worker 2 stays healthy.
    let links: Vec<Box<dyn Link>> = vec![spawn_dying_worker(1, 2), spawn_healthy_worker(2)];
    let mut dist = SessionBuilder::new().trainer(cfg.clone()).links(links).build().unwrap();
    assert_eq!(dist.trainer().alive_workers(), 2);

    let mut losses = Vec::new();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let res = dist.step(&batch).unwrap();
        losses.push(res.loss);
    }
    // The dying worker was dropped; training continued on master + worker 2.
    assert_eq!(dist.trainer().alive_workers(), 1);
    // Post-death shards must cover both layers over the 2 survivors.
    for layer in [1, 2] {
        let covered: usize = dist.trainer().shards(layer).iter().map(|s| s.len()).sum();
        assert_eq!(covered, rt.arch().kernels(layer));
        assert!(
            dist.trainer().shards(layer).iter().all(|s| s.device != 1),
            "dead device still scheduled"
        );
    }
    // And the numerics still match a single-device reference.
    let mut single = convdist::baselines::SingleDeviceTrainer::new(
        rt.clone(),
        &cfg,
        Throttle::none(),
    )
    .unwrap();
    let mut ds2 = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 31);
    let mut ref_losses = Vec::new();
    for step in 0..cfg.steps {
        let batch = ds2.batch(arch.batch, step).unwrap();
        ref_losses.push(single.step(&batch).unwrap().0);
    }
    for (i, (a, b)) in losses.iter().zip(&ref_losses).enumerate() {
        assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "step {i}: {a} vs {b}");
    }
    dist.shutdown().unwrap();
}

#[test]
fn all_workers_dead_falls_back_to_master_only() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(2);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 32);

    let links: Vec<Box<dyn Link>> = vec![spawn_dying_worker(1, 0)];
    let mut dist = SessionBuilder::new().trainer(cfg.clone()).links(links).build().unwrap();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let res = dist.step(&batch).unwrap();
        assert!(res.loss.is_finite());
    }
    assert_eq!(dist.trainer().alive_workers(), 0);
    // Master holds every kernel now.
    for layer in [1, 2] {
        assert!(dist.trainer().shards(layer).iter().all(|s| s.device == 0));
    }
    dist.shutdown().unwrap();
}
