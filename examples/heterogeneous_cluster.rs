//! The paper's headline scenario: a *heterogeneous* cluster (the four
//! Table 2 laptops, virtual-time emulated) training with Eq. 1 balanced
//! shards vs the naive equal split that data-parallel systems force.
//!
//! Demonstrates §4.1.1's argument end-to-end on the real protocol: the
//! balanced partition loads each device in proportion to its speed, so the
//! conv phase finishes sooner than the equal split that makes the slowest
//! laptop convolve as many kernels as the fastest.
//!
//! ```sh
//! cargo run --release --example heterogeneous_cluster
//! ```
//!
//! With `--adaptive`, runs the adaptive-scheduler demo instead: an equal
//! 4-device fleet where one worker thermally throttles 8x mid-training.
//! The static Eq. 1 partition (calibrated once) is held hostage by the
//! straggler; the adaptive scheduler detects the drift from its EWMA
//! telemetry, re-runs Eq. 1 over the observed rates and recovers most of
//! the speedup a statically re-calibrated oracle would get (DESIGN.md §5).
//!
//! ```sh
//! cargo run --release --example heterogeneous_cluster -- --adaptive
//! ```
//!
//! Both demos are four lines of `SessionBuilder` each — the fleet shape,
//! throttle plans and scheduling mode are axes of one builder, and the
//! re-shard notices arrive through the event hook.

use convdist::config::TrainerConfig;
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::{paper_cpus, Throttle, ThrottlePlan};
use convdist::metrics::Breakdown;
use convdist::sched::{AdaptiveConfig, ShardTable};
use convdist::session::{Event, Session, SessionBuilder};

fn avg_steps(
    session: &mut Session,
    ds: &mut SyntheticCifar,
    batchsz: usize,
    steps: usize,
) -> anyhow::Result<Breakdown> {
    let mut cum = Breakdown::default();
    for step in 0..steps {
        let res = session.step(&ds.batch(batchsz, step)?)?;
        cum.add(&res.breakdown);
    }
    Ok(cum.scale(1.0 / steps as f64))
}

fn main() -> anyhow::Result<()> {
    if std::env::args().any(|a| a == "--adaptive") {
        return adaptive_demo();
    }
    static_demo()
}

// ---------------------------------------------------------------------------
// Default mode: Eq. 1 balanced vs equal split on the paper's Table 2 fleet
// ---------------------------------------------------------------------------

fn static_demo() -> anyhow::Result<()> {
    let steps = 3;
    let cfg = TrainerConfig { steps, calib_rounds: 2, ..Default::default() };

    // Virtual-time profiles of the paper's Table 2 CPUs (PC1..PC4 =
    // 20/38/24/42 GFLOPS ratios), fastest pinned at 1 virtual GFLOPS.
    let profiles = paper_cpus();
    let virt = Throttle::virtual_cluster(&profiles, 1.0);
    println!("devices: {:?}\n", profiles.iter().map(|p| p.name).collect::<Vec<_>>());

    // --- 1 device (PC1-speed master only): the paper's reference ------------
    let mut solo =
        SessionBuilder::new().trainer(cfg.clone()).master_throttle(virt[0]).build()?;
    let arch = solo.runtime().arch().clone();
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 5);
    let _ = solo.step(&ds.batch(arch.batch, 999)?)?; // warm executables
    let solo_avg = avg_steps(&mut solo, &mut ds, arch.batch, steps)?;
    println!("1 device (PC1)        {solo_avg}");
    solo.shutdown()?;

    // --- 4 devices, Eq. 1 balanced (the paper's technique) ------------------
    let mut balanced = SessionBuilder::new()
        .trainer(cfg)
        .master_throttle(virt[0])
        .workers(&virt[1..])
        .build()?;
    let _ = balanced.step(&ds.batch(arch.batch, 999)?)?;
    let bal_avg = avg_steps(&mut balanced, &mut ds, arch.batch, steps)?;
    println!("4 devices, Eq.1       {bal_avg}");
    println!("   conv2 shards: {}", ShardTable(balanced.trainer().shards(2)));

    // --- same 4 devices, naive equal split (ablation) ------------------------
    balanced.trainer_mut().partition_equal()?;
    let eq_avg = avg_steps(&mut balanced, &mut ds, arch.batch, steps)?;
    println!("4 devices, equal      {eq_avg}");
    println!("   conv2 shards: {}", ShardTable(balanced.trainer().shards(2)));
    balanced.shutdown()?;

    let s_bal = solo_avg.total().as_secs_f64() / bal_avg.total().as_secs_f64();
    let s_eq = solo_avg.total().as_secs_f64() / eq_avg.total().as_secs_f64();
    println!("\nspeedup vs 1 device:  Eq.1 balanced {s_bal:.2}x   equal split {s_eq:.2}x");
    println!("(paper Table 4: 4 heterogeneous CPUs reach 1.56-3.28x depending on arch)");
    anyhow::ensure!(s_bal > 1.0, "balanced cluster must beat a single device");
    anyhow::ensure!(s_bal > s_eq * 0.98, "Eq.1 must not lose to the equal split");
    println!("heterogeneous_cluster OK");
    Ok(())
}

// ---------------------------------------------------------------------------
// --adaptive: recover from a mid-training 8x degradation
// ---------------------------------------------------------------------------

fn adaptive_demo() -> anyhow::Result<()> {
    let steps = 12usize;
    let degrade_at_step = 3usize;
    let cfg = TrainerConfig { steps, calib_rounds: 1, ..Default::default() };

    let fast = Throttle::virtual_gflops(2.0);
    let slow = Throttle::virtual_gflops(0.25); // 8x thermal throttle
    let degrading = ThrottlePlan::degrade_after(fast, 4 * degrade_at_step as u64, slow);
    let plans = vec![degrading, ThrottlePlan::fixed(fast), ThrottlePlan::fixed(fast)];
    println!(
        "fleet: 4 equal virtual devices; worker 1 throttles 8x at step {degrade_at_step}\n"
    );

    let run = |label: &'static str, adaptive: AdaptiveConfig| -> anyhow::Result<Vec<f64>> {
        let mut session = SessionBuilder::new()
            .trainer(cfg.clone())
            .master_throttle(fast)
            .worker_plans(plans.clone())
            .adaptive(adaptive)
            .on_event(move |ev| {
                if let Event::Repartitioned { step } = ev {
                    println!("[{label}] step {step}: fleet re-sharded");
                }
            })
            .build()?;
        let arch = session.runtime().arch().clone();
        let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 5);
        println!(
            "[{label}] initial conv2 shards: {}",
            ShardTable(session.trainer().shards(2))
        );
        let mut secs = Vec::with_capacity(steps);
        for step in 0..steps {
            let t0 = std::time::Instant::now();
            let r = session.step(&ds.batch(arch.batch, step)?)?;
            secs.push(t0.elapsed().as_secs_f64());
            if r.repartitioned {
                println!(
                    "[{label}] step {step}: new conv2 shards {}",
                    ShardTable(session.trainer().shards(2))
                );
            }
        }
        println!("[{label}] {}", session.trainer().sched_stats());
        session.shutdown()?;
        Ok(secs)
    };

    let adaptive_cfg = AdaptiveConfig {
        alpha: 0.5,
        warmup_steps: 1,
        imbalance_threshold: 0.2,
        cooldown_steps: 2,
        heartbeat_every: 0,
        ..Default::default()
    };
    let static_secs = run("static  ", AdaptiveConfig::disabled())?;
    let adaptive_secs = run("adaptive", adaptive_cfg)?;

    // Oracle: a fleet whose calibration already saw the degraded speed.
    let oracle_secs = {
        let mut oracle = SessionBuilder::new()
            .trainer(cfg)
            .master_throttle(fast)
            .workers(&[slow, fast, fast])
            .build()?;
        let arch = oracle.runtime().arch().clone();
        let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 5);
        let mut secs = Vec::new();
        for step in 0..6 {
            let t0 = std::time::Instant::now();
            oracle.step(&ds.batch(arch.batch, step)?)?;
            secs.push(t0.elapsed().as_secs_f64());
        }
        oracle.shutdown()?;
        secs
    };

    println!("\nstep   static(s)  adaptive(s)");
    for step in 0..steps {
        println!("{step:>4}   {:>8.3}   {:>10.3}", static_secs[step], adaptive_secs[step]);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let s_tail = mean(&static_secs[steps - 4..]);
    let a_tail = mean(&adaptive_secs[steps - 4..]);
    let o_tail = mean(&oracle_secs[1..]);
    let recovered = ((s_tail - a_tail) / (s_tail - o_tail).max(1e-9)).clamp(0.0, 1.0);
    println!(
        "\nsteady-state step time: static {s_tail:.3}s  adaptive {a_tail:.3}s  oracle {o_tail:.3}s"
    );
    println!("adaptive recovers {:.0}% of the static-oracle speedup", 100.0 * recovered);
    anyhow::ensure!(
        a_tail <= s_tail * 1.02,
        "adaptive steady state must not lose to the degraded static partition"
    );
    println!("heterogeneous_cluster --adaptive OK");
    Ok(())
}
