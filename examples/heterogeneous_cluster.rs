//! The paper's headline scenario: a *heterogeneous* cluster (the four
//! Table 2 laptops, virtual-time emulated) training with Eq. 1 balanced
//! shards vs the naive equal split that data-parallel systems force.
//!
//! Demonstrates §4.1.1's argument end-to-end on the real protocol: the
//! balanced partition loads each device in proportion to its speed, so the
//! conv phase finishes sooner than the equal split that makes the slowest
//! laptop convolve as many kernels as the fastest.
//!
//! ```sh
//! make artifacts && cargo run --release --example heterogeneous_cluster
//! ```

use convdist::cluster::{spawn_inproc, DistTrainer};
use convdist::config::TrainerConfig;
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::{paper_cpus, Throttle};
use convdist::metrics::Breakdown;
use convdist::runtime::Runtime;

fn avg_steps(
    trainer: &mut DistTrainer,
    ds: &mut SyntheticCifar,
    batchsz: usize,
    steps: usize,
) -> anyhow::Result<Breakdown> {
    let mut cum = Breakdown::default();
    for step in 0..steps {
        let res = trainer.step(&ds.batch(batchsz, step)?)?;
        cum.add(&res.breakdown);
    }
    Ok(cum.scale(1.0 / steps as f64))
}

fn shard_desc(trainer: &DistTrainer, layer: usize) -> String {
    trainer
        .shards(layer)
        .iter()
        .map(|s| format!("dev{}={}", s.device, s.len()))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() -> anyhow::Result<()> {
    let steps = 3;
    let artifacts = convdist::artifacts_dir();
    let rt = Runtime::open(&artifacts)?;
    let arch = rt.arch().clone();
    let cfg = TrainerConfig { steps, calib_rounds: 2, ..Default::default() };
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 5);

    // Virtual-time profiles of the paper's Table 2 CPUs (PC1..PC4 =
    // 20/38/24/42 GFLOPS ratios), fastest pinned at 1 virtual GFLOPS.
    let profiles = paper_cpus();
    let virt = Throttle::virtual_cluster(&profiles, 1.0);
    println!("devices: {:?}\n", profiles.iter().map(|p| p.name).collect::<Vec<_>>());

    // --- 1 device (PC1-speed master only): the paper's reference ------------
    let mut solo = DistTrainer::new(rt.clone(), vec![], &cfg, virt[0])?;
    let _ = solo.step(&ds.batch(arch.batch, 999)?)?; // warm executables
    let solo_avg = avg_steps(&mut solo, &mut ds, arch.batch, steps)?;
    println!("1 device (PC1)        {solo_avg}");
    solo.shutdown()?;

    // --- 4 devices, Eq. 1 balanced (the paper's technique) ------------------
    let mut cluster = spawn_inproc(artifacts.clone(), &virt[1..], None);
    let mut balanced = DistTrainer::new(rt.clone(), cluster.take_links(), &cfg, virt[0])?;
    let _ = balanced.step(&ds.batch(arch.batch, 999)?)?;
    let bal_avg = avg_steps(&mut balanced, &mut ds, arch.batch, steps)?;
    println!("4 devices, Eq.1       {bal_avg}");
    println!("   conv2 shards: {}", shard_desc(&balanced, 2));

    // --- same 4 devices, naive equal split (ablation) ------------------------
    balanced.partition_equal()?;
    let eq_avg = avg_steps(&mut balanced, &mut ds, arch.batch, steps)?;
    println!("4 devices, equal      {eq_avg}");
    println!("   conv2 shards: {}", shard_desc(&balanced, 2));
    balanced.shutdown()?;
    cluster.join()?;

    let s_bal = solo_avg.total().as_secs_f64() / bal_avg.total().as_secs_f64();
    let s_eq = solo_avg.total().as_secs_f64() / eq_avg.total().as_secs_f64();
    println!("\nspeedup vs 1 device:  Eq.1 balanced {s_bal:.2}x   equal split {s_eq:.2}x");
    println!("(paper Table 4: 4 heterogeneous CPUs reach 1.56-3.28x depending on arch)");
    anyhow::ensure!(s_bal > 1.0, "balanced cluster must beat a single device");
    anyhow::ensure!(s_bal > s_eq * 0.98, "Eq.1 must not lose to the equal split");
    println!("heterogeneous_cluster OK");
    Ok(())
}
