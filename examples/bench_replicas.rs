//! `cargo run --release --example bench_replicas`
//!
//! Emits `BENCH_replicas.json`: the replica-tier sweep (DESIGN.md §14) —
//! 1, 2 and 4 replica fleets over the same global batch, each n > 1 run
//! under both all-reduce strategies, recording mean step time and the bytes
//! the gradient fabric moved.  CI uploads the file as a workflow artifact
//! so scale-out overhead is tracked over time, and gates on the wire-cost
//! contract: the ring strategy must never move more bytes than the
//! master-rooted tree.

use std::fmt::Write as _;
use std::time::Instant;

use convdist::data::default_dataset;
use convdist::devices::Throttle;
use convdist::replica::AllReduce;
use convdist::runtime::ArchSpec;
use convdist::session::SessionBuilder;

const STEPS: usize = 6;

struct Point {
    replicas: usize,
    allreduce: &'static str,
    mean_step_ms: f64,
    allreduce_bytes: u64,
}

fn run_point(arch: &ArchSpec, n: usize, strategy: AllReduce) -> anyhow::Result<Point> {
    let cfg = convdist::config::TrainerConfig {
        steps: STEPS,
        calib_rounds: 1,
        ..Default::default()
    };
    let seed = cfg.seed;
    let mut b = SessionBuilder::new()
        .arch_spec(arch.clone())
        .trainer(cfg)
        .master_throttle(Throttle::none())
        .workers(&[Throttle::none()]);
    if n > 1 {
        b = b.replicas(n).allreduce(strategy);
    }
    let mut session = b.build()?;
    let mut ds = default_dataset(arch.img, arch.in_ch, arch.num_classes, seed);
    let mut total = 0f64;
    for step in 0..STEPS {
        let batch = ds.batch(arch.batch, step)?;
        let t = Instant::now();
        session.step(&batch)?;
        total += t.elapsed().as_secs_f64() * 1e3;
    }
    let bytes = session.allreduce_bytes();
    session.shutdown()?;
    Ok(Point {
        replicas: n,
        allreduce: if n > 1 { strategy.name() } else { "none" },
        mean_step_ms: total / STEPS as f64,
        allreduce_bytes: bytes,
    })
}

fn main() -> anyhow::Result<()> {
    // 4+8 kernels over a global batch of 8: divisible by every fleet count
    // in the sweep, small enough that 4 fleets stay in milliseconds.
    let arch = ArchSpec::from_geometry(4, 8, 8);
    let mut points: Vec<Point> = Vec::new();
    for n in [1usize, 2, 4] {
        if n == 1 {
            points.push(run_point(&arch, n, AllReduce::Master)?);
        } else {
            points.push(run_point(&arch, n, AllReduce::Master)?);
            points.push(run_point(&arch, n, AllReduce::Ring)?);
        }
    }

    let mut json = String::new();
    writeln!(json, "{{")?;
    writeln!(json, "  \"name\": \"replica_allreduce_sweep\",")?;
    writeln!(json, "  \"arch\": \"{}@{}\",", arch.label(), arch.batch)?;
    writeln!(json, "  \"steps\": {STEPS},")?;
    writeln!(json, "  \"sweep\": [")?;
    for (i, p) in points.iter().enumerate() {
        let comma = if i + 1 < points.len() { "," } else { "" };
        writeln!(
            json,
            "    {{\"replicas\": {}, \"allreduce\": \"{}\", \"mean_step_ms\": {:.3}, \
             \"allreduce_bytes\": {}}}{comma}",
            p.replicas, p.allreduce, p.mean_step_ms, p.allreduce_bytes
        )?;
    }
    writeln!(json, "  ]")?;
    writeln!(json, "}}")?;
    std::fs::write("BENCH_replicas.json", &json)?;

    for p in &points {
        println!(
            "replicas {} ({:>6}): step {:.3} ms  all-reduce {} B",
            p.replicas, p.allreduce, p.mean_step_ms, p.allreduce_bytes
        );
    }
    // The wire-cost contract: for every fleet count, ring <= master.
    for n in [2usize, 4] {
        let bytes = |s: &str| {
            points
                .iter()
                .find(|p| p.replicas == n && p.allreduce == s)
                .map(|p| p.allreduce_bytes)
                .unwrap_or(0)
        };
        let (master, ring) = (bytes("master"), bytes("ring"));
        anyhow::ensure!(master > 0 && ring > 0, "replicas {n}: fabric moved no bytes");
        anyhow::ensure!(
            ring <= master,
            "replicas {n}: ring moved {ring} bytes > master {master}"
        );
    }
    let single = points.iter().find(|p| p.replicas == 1).unwrap();
    anyhow::ensure!(single.allreduce_bytes == 0, "a single fleet must have no fabric");
    println!("BENCH_replicas.json written ({} sweep points)", points.len());
    Ok(())
}
