//! `cargo run --release --example bench_obs`
//!
//! Emits `BENCH_obs.json` and gates the tracing overhead: on the tiny
//! preset with virtual-time throttles (sleep-dominated, so step walls are
//! stable), the median step time of a fully traced run (`--trace`: spans +
//! run log + metrics) must stay within 2% of an unobserved run.  CI uploads
//! the file as a workflow artifact so the overhead is tracked over time.

use std::fmt::Write as _;
use std::time::Instant;

use convdist::config::TrainerConfig;
use convdist::data::default_dataset;
use convdist::devices::Throttle;
use convdist::obs::ObsConfig;
use convdist::runtime::ArchSpec;
use convdist::session::SessionBuilder;

const STEPS: usize = 30;
const WARMUP: usize = 3;

/// Median step wall (ms) over a tiny-preset fleet, warmup excluded.
fn median_step_ms(obs: Option<ObsConfig>) -> anyhow::Result<f64> {
    // 0.1 virtual GFLOPS: the padded sleep dominates real compute in both
    // runs, so the measured delta isolates the tracer's own cost.
    let v = Throttle::virtual_gflops(0.1);
    let mut b = SessionBuilder::new()
        .arch_spec(ArchSpec::tiny())
        .trainer(TrainerConfig {
            steps: STEPS,
            calib_rounds: 1,
            log_every: 10_000,
            ..Default::default()
        })
        .master_throttle(v)
        .workers(&[v, v]);
    if let Some(cfg) = obs {
        b = b.observe(cfg);
    }
    let mut session = b.build()?;
    let arch = session.runtime().arch().clone();
    let mut ds = default_dataset(arch.img, arch.in_ch, arch.num_classes, 42);
    let mut times_ms = Vec::with_capacity(STEPS);
    for step in 0..STEPS {
        let batch = ds.batch(arch.batch, step)?;
        let t0 = Instant::now();
        session.step(&batch)?;
        times_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    session.shutdown()?;
    let mut tail = times_ms[WARMUP..].to_vec();
    tail.sort_by(|a, b| a.total_cmp(b));
    Ok(tail[tail.len() / 2])
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("convdist_bench_obs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let base_ms = median_step_ms(None)?;
    let traced_ms = median_step_ms(Some(ObsConfig::trace_to(&dir)))?;
    let overhead_pct = ((traced_ms - base_ms) / base_ms * 100.0).max(0.0);
    let span_lines = std::fs::read_to_string(dir.join("run.jsonl"))
        .map(|t| t.lines().filter(|l| l.contains("\"type\":\"span\"")).count())
        .unwrap_or(0);
    let _ = std::fs::remove_dir_all(&dir);

    let mut json = String::new();
    writeln!(json, "{{")?;
    writeln!(json, "  \"name\": \"obs_tracing_overhead\",")?;
    writeln!(json, "  \"arch\": \"tiny\",")?;
    writeln!(json, "  \"steps\": {STEPS},")?;
    writeln!(json, "  \"base_step_ms\": {base_ms:.4},")?;
    writeln!(json, "  \"traced_step_ms\": {traced_ms:.4},")?;
    writeln!(json, "  \"span_lines\": {span_lines},")?;
    writeln!(json, "  \"overhead_pct\": {overhead_pct:.3}")?;
    writeln!(json, "}}")?;
    std::fs::write("BENCH_obs.json", &json)?;

    println!(
        "BENCH_obs.json written: base {base_ms:.3} ms/step, traced {traced_ms:.3} ms/step \
         ({span_lines} spans logged) -> {overhead_pct:.2}% overhead"
    );
    anyhow::ensure!(span_lines > 0, "the traced run must record spans");
    anyhow::ensure!(
        overhead_pct < 2.0,
        "tracing overhead {overhead_pct:.2}% exceeds the 2% gate \
         (base {base_ms:.3} ms vs traced {traced_ms:.3} ms)"
    );
    Ok(())
}
