//! `cargo run --release --example bench_gemm`
//!
//! Emits `BENCH_gemm.json`: naive (`linalg::reference`, the pre-engine
//! triple loops) vs blocked/packed/SIMD (`linalg`) GFLOP/s across the
//! paper-relevant im2col GEMM shapes — the 500- and 1500-kernel CIFAR conv
//! layers of the paper's largest net, the native default 16:32 geometry,
//! the FC head and a square baseline.  CI uploads the file as a workflow
//! artifact so the engine's speedup is tracked over time, and this binary
//! enforces the acceptance floor: >= 3x over naive on the CIFAR conv
//! shapes, measured *serial vs serial* — the conv hot path runs its
//! per-image GEMMs serially inside the batch-parallel pool, so that is the
//! configuration the gate protects (the top-level parallel rate is
//! reported alongside, ungated).  Blocked-vs-naive conformance must sit
//! within the f32 noise of the summation-order change.

use std::fmt::Write as _;
use std::time::Duration;

use convdist::linalg::{self, reference};
use convdist::tensor::Pcg32;
use convdist::util::bench::Bencher;

struct ShapeSpec {
    label: &'static str,
    m: usize,
    kd: usize,
    n: usize,
    /// Counts toward the CIFAR-conv speedup gate.
    conv: bool,
}

/// `m` = kernels, `kd` = in_ch * kh * kw, `n` = out_h * out_w (per-image
/// im2col product, exactly what `kernels::conv2d_fwd` runs per batch item).
const SHAPES: [ShapeSpec; 5] = [
    // Paper 500:1500 net, conv1: 500 kernels over RGB 5x5, 32x32 -> 28x28.
    ShapeSpec { label: "conv1_k500_500x75x784", m: 500, kd: 75, n: 784, conv: true },
    // Paper 500:1500 net, conv2: 1500 kernels over 500 ch, 14x14 -> 10x10.
    ShapeSpec { label: "conv2_k1500_1500x12500x100", m: 1500, kd: 12500, n: 100, conv: true },
    // Native default arch (16:32 @ 64), conv1 per image.
    ShapeSpec { label: "conv1_native_16x75x784", m: 16, kd: 75, n: 784, conv: false },
    // FC head: batch 64, 800 features, 10 classes.
    ShapeSpec { label: "fc_head_64x800x10", m: 64, kd: 800, n: 10, conv: false },
    // Square baseline for cross-machine comparison.
    ShapeSpec { label: "square_256x256x256", m: 256, kd: 256, n: 256, conv: false },
];

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn main() -> anyhow::Result<()> {
    let bl = linalg::blocks();
    let isa = linalg::isa();
    println!(
        "linalg engine: isa {}  blocks mc={} kc={} nc={}  rayon threads {}",
        isa.label(),
        bl.mc,
        bl.kc,
        bl.nc,
        rayon::current_num_threads()
    );

    // 1-thread pool for the gated serial measurements (see below).
    let serial_pool = rayon::ThreadPoolBuilder::new().num_threads(1).build()?;

    let mut rng = Pcg32::seed(0xBE9C);
    let mut rows = Vec::new();
    let mut min_conv_speedup = f64::MAX;
    let mut worst_err = 0f32;
    for sh in &SHAPES {
        let (m, kd, n) = (sh.m, sh.kd, sh.n);
        let flops = linalg::gemm_flops(m, kd, n);
        let a: Vec<f32> = (0..m * kd).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..kd * n).map(|_| rng.next_gaussian()).collect();

        // Conformance first: one fresh accumulation each way.  The two
        // paths differ only in f32 summation order, which grows like
        // sqrt(kd) for gaussian data.
        let mut got = vec![0f32; m * n];
        let mut want = vec![0f32; m * n];
        linalg::gemm(&a, &b, m, kd, n, &mut got);
        reference::gemm(&a, &b, m, kd, n, &mut want);
        let err = max_abs_diff(&got, &want);
        let tol = 1e-4 * (kd as f32).sqrt().max(1.0);
        anyhow::ensure!(
            err <= tol,
            "{}: blocked diverged from naive by {err} (tol {tol})",
            sh.label
        );
        worst_err = worst_err.max(err);

        // Naive timing: one warmup + one timed run for the multi-GFLOP
        // shapes (a naive pass of conv2_k1500 is seconds; the warmup keeps
        // the comparison symmetric with the warmed blocked side instead of
        // charging naive for first-touch faults), best-of-many otherwise.
        let naive_bench = if flops > 1e9 {
            Bencher { budget: Duration::ZERO, max_iters: 1, warmup: 1 }
        } else {
            Bencher { budget: Duration::from_millis(300), max_iters: 50, warmup: 1 }
        };
        let blocked_bench =
            Bencher { budget: Duration::from_millis(400), max_iters: 60, warmup: 1 };
        let mut out = vec![0f32; m * n];
        let rn = naive_bench.run(&format!("naive        {}", sh.label), || {
            out.fill(0.0);
            reference::gemm(&a, &b, m, kd, n, &mut out);
        });
        // The gated number is SERIAL blocked vs serial naive: the conv hot
        // path runs its per-image GEMMs serially inside the batch-parallel
        // rayon pool (linalg's nested-parallelism guard), so that is the
        // configuration the >= 3x floor must protect.  Running inside a
        // 1-thread pool makes current_thread_index() Some, forcing the
        // same serial path the kernels see.
        let rb = blocked_bench.run(&format!("blocked(1t)  {}", sh.label), || {
            serial_pool.install(|| {
                out.fill(0.0);
                linalg::gemm(&a, &b, m, kd, n, &mut out);
            })
        });
        // The parallel number (what a lone top-level GEMM achieves) is
        // reported alongside but not gated.
        let rp = blocked_bench.run(&format!("blocked(par) {}", sh.label), || {
            out.fill(0.0);
            linalg::gemm(&a, &b, m, kd, n, &mut out);
        });
        let g_naive = flops / 1e9 / rn.min.as_secs_f64();
        let g_blocked = flops / 1e9 / rb.min.as_secs_f64();
        let g_blocked_par = flops / 1e9 / rp.min.as_secs_f64();
        let speedup = g_blocked / g_naive;
        println!(
            "  {:<28} naive {g_naive:7.2}  blocked-serial {g_blocked:7.2}  \
             blocked-par {g_blocked_par:7.2} GFLOP/s  serial speedup {speedup:5.2}x",
            sh.label
        );
        if sh.conv {
            min_conv_speedup = min_conv_speedup.min(speedup);
        }
        rows.push((sh, g_naive, g_blocked, g_blocked_par, speedup, err));
    }

    let mut json = String::new();
    writeln!(json, "{{")?;
    writeln!(json, "  \"name\": \"gemm_native_engine\",")?;
    writeln!(json, "  \"isa\": \"{}\",", isa.label())?;
    writeln!(json, "  \"blocks\": {{\"mc\": {}, \"kc\": {}, \"nc\": {}}},", bl.mc, bl.kc, bl.nc)?;
    writeln!(json, "  \"threads\": {},", rayon::current_num_threads())?;
    writeln!(json, "  \"shapes\": [")?;
    for (i, (sh, gn, gb, gp, sp, err)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        writeln!(
            json,
            "    {{\"label\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"conv\": {}, \
             \"gflops_naive\": {gn:.4}, \"gflops_blocked_serial\": {gb:.4}, \
             \"gflops_blocked_parallel\": {gp:.4}, \"serial_speedup\": {sp:.4}, \
             \"max_abs_err\": {err:.3e}}}{comma}",
            sh.label, sh.m, sh.kd, sh.n, sh.conv
        )?;
    }
    writeln!(json, "  ],")?;
    writeln!(json, "  \"summary\": {{")?;
    writeln!(json, "    \"min_conv_speedup\": {min_conv_speedup:.4},")?;
    writeln!(json, "    \"worst_max_abs_err\": {worst_err:.3e}")?;
    writeln!(json, "  }}")?;
    writeln!(json, "}}")?;
    std::fs::write("BENCH_gemm.json", &json)?;
    println!(
        "BENCH_gemm.json written: min CIFAR-conv serial speedup {min_conv_speedup:.2}x, \
         worst max-abs err {worst_err:.2e}"
    );
    anyhow::ensure!(
        min_conv_speedup >= 3.0,
        "serial blocked GEMM must be >= 3x serial naive on the CIFAR conv shapes, \
         got {min_conv_speedup:.2}x"
    );
    Ok(())
}
