//! Quickstart: one distributed training batch, end to end, through the
//! unified session API.
//!
//! Composes a session (master + 2 workers, one half-speed so the Eq. 1
//! partition is visibly unequal), shows the kernel partition, runs one batch
//! through distributed forward + backward + SGD, and prints the paper's
//! Comm/Conv/Comp breakdown — with the step line delivered by an event
//! observer instead of a hand-rolled logging loop.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use convdist::config::TrainerConfig;
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::session::{Event, SessionBuilder};

fn main() -> anyhow::Result<()> {
    // Master + two workers; worker 2 emulates a half-speed device.
    let cfg = TrainerConfig { steps: 1, calib_rounds: 2, ..Default::default() };
    let mut session = SessionBuilder::new()
        .workers(&[Throttle::virtual_gflops(2.0), Throttle::virtual_gflops(1.0)])
        .master_throttle(Throttle::virtual_gflops(2.0))
        .trainer(cfg)
        .on_event(|ev| {
            if let Event::StepCompleted { loss, devices, breakdown, bytes_moved, .. } = ev {
                println!("\none distributed step:");
                println!("  loss        {loss:.4}");
                println!("  devices     {devices}");
                println!("  wire        {:.2} MiB", *bytes_moved as f64 / (1 << 20) as f64);
                println!("  breakdown   {breakdown}");
            }
        })
        .build()?;

    let arch = session.runtime().arch().clone();
    println!(
        "session up: arch {} ({} conv layers), batch {}, platform {}",
        arch.label(),
        arch.num_convs(),
        arch.batch,
        session.runtime().platform()
    );
    println!("\ncalibration probe times (s): {:?}", session.trainer().probe_times());
    for layer in 1..=arch.num_convs() {
        let desc: Vec<String> = session
            .trainer()
            .shards(layer)
            .iter()
            .map(|s| {
                format!("device {} -> kernels {}..{} (bucket {})", s.device, s.lo, s.hi, s.bucket)
            })
            .collect();
        println!("conv{layer} partition: {}", desc.join(", "));
    }

    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 1);
    let batch = ds.batch(arch.batch, 0)?;
    session.step(&batch)?;

    session.shutdown()?;
    println!("\nquickstart OK");
    Ok(())
}
