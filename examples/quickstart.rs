//! Quickstart: one distributed training batch, end to end.
//!
//! Spins up an in-process cluster (master + 2 workers), calibrates, shows
//! the Eq. 1 kernel partition, runs one batch through distributed forward +
//! backward + SGD, and prints the paper's Comm/Conv/Comp breakdown.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use convdist::cluster::{spawn_inproc, DistTrainer};
use convdist::config::TrainerConfig;
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let artifacts = convdist::artifacts_dir();
    let rt = Runtime::open(&artifacts)?;
    let arch = rt.arch().clone();
    println!(
        "loaded {} AOT executables  (arch {}, batch {}, platform {})",
        rt.manifest().executables.len(),
        arch.label(),
        arch.batch,
        rt.platform()
    );

    // Master + two workers; worker 2 emulates a half-speed device so the
    // Eq. 1 partition is visibly unequal.
    let throttles = [Throttle::virtual_gflops(2.0), Throttle::virtual_gflops(1.0)];
    let mut cluster = spawn_inproc(artifacts, &throttles, None);
    let cfg = TrainerConfig { steps: 1, calib_rounds: 2, ..Default::default() };
    let mut trainer =
        DistTrainer::new(rt.clone(), cluster.take_links(), &cfg, Throttle::virtual_gflops(2.0))?;

    println!("\ncalibration probe times (s): {:?}", trainer.probe_times());
    for layer in 1..=arch.num_convs() {
        let desc: Vec<String> = trainer
            .shards(layer)
            .iter()
            .map(|s| format!("device {} -> kernels {}..{} (bucket {})", s.device, s.lo, s.hi, s.bucket))
            .collect();
        println!("conv{layer} partition: {}", desc.join(", "));
    }

    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 1);
    let batch = ds.batch(arch.batch, 0)?;
    let res = trainer.step(&batch)?;
    println!("\none distributed step:");
    println!("  loss        {:.4}", res.loss);
    println!("  devices     {}", res.devices);
    println!("  wire        {:.2} MiB", res.bytes_moved as f64 / (1 << 20) as f64);
    println!("  breakdown   {}", res.breakdown);

    trainer.shutdown()?;
    cluster.join()?;
    println!("\nquickstart OK");
    Ok(())
}
