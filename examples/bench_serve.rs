//! `cargo run --release --example bench_serve`
//!
//! Load generator for `convdist serve` (DESIGN.md §13): sweeps offered QPS
//! against a tiny-preset fleet twice — dynamic batcher off (`max_batch 1`)
//! and on (`max_batch` = the top batch rung) — and emits `BENCH_serve.json`
//! with p50/p99 latency and achieved throughput per sweep point.  The gate:
//! at the saturating offered rate the batcher must not lose to batch-of-one
//! on p50 (it amortizes per-dispatch scatter/gather over the whole rung).
//! CI uploads the file as a workflow artifact so the curve is tracked.

use std::fmt::Write as _;
use std::path::Path;
use std::time::{Duration, Instant};

use convdist::config::{ServeConfig, TrainerConfig};
use convdist::devices::Throttle;
use convdist::model::Params;
use convdist::runtime::ArchSpec;
use convdist::serve::ServeClient;
use convdist::session::{ArchSource, Checkpoint, SessionBuilder};
use convdist::tensor::{Pcg32, Tensor};

const CONNECTIONS: usize = 4;
const REQUESTS_PER_CONN: usize = 20;
/// Offered request rates (whole fleet, not per connection).  The top entry
/// is far past what serial batch-of-one dispatch sustains, so it saturates.
const QPS_SWEEP: &[f64] = &[25.0, 100.0, 800.0];

struct Point {
    offered_qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    achieved_qps: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

/// One serve deployment, swept across `QPS_SWEEP` with open-loop pacing:
/// each of `CONNECTIONS` clients fires on its schedule (blocking on the
/// reply, so in-flight is bounded by the connection count, like a real
/// frontend pool).
fn run_mode(ckpt: &Path, batcher: bool) -> anyhow::Result<Vec<Point>> {
    let infer = SessionBuilder::new()
        .arch(ArchSource::Preset("tiny".into()))
        .trainer(TrainerConfig { calib_rounds: 1, ..Default::default() })
        .workers(&[Throttle::none(); 2])
        .inference(ckpt)?;
    let arch = infer.runtime().arch().clone();
    let top_rung = arch.batch_buckets.last().copied().unwrap_or(arch.batch);
    let scfg = if batcher {
        ServeConfig { max_delay_ms: 5, max_batch: top_rung }
    } else {
        ServeConfig { max_delay_ms: 0, max_batch: 1 }
    };
    let serving = infer.serve("127.0.0.1:0", scfg)?;
    let addr = serving.addr().to_string();

    let mut points = Vec::new();
    for &qps in QPS_SWEEP {
        let interval = Duration::from_secs_f64(CONNECTIONS as f64 / qps);
        let wall0 = Instant::now();
        let handles: Vec<_> = (0..CONNECTIONS)
            .map(|t| {
                let addr = addr.clone();
                let shape = [arch.in_ch, arch.img, arch.img];
                std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                    let mut c = ServeClient::connect(&addr)?;
                    let mut rng = Pcg32::seed_stream(0xBE9C, t as u64);
                    let t0 = Instant::now();
                    let mut lat = Vec::with_capacity(REQUESTS_PER_CONN);
                    for i in 0..REQUESTS_PER_CONN {
                        let due = interval.mul_f64(i as f64);
                        let now = t0.elapsed();
                        if now < due {
                            std::thread::sleep(due - now);
                        }
                        let img = Tensor::randn(&shape, &mut rng);
                        let s = Instant::now();
                        c.classify(&img)?;
                        lat.push(s.elapsed().as_secs_f64() * 1e3);
                    }
                    Ok(lat)
                })
            })
            .collect();
        let mut lat = Vec::new();
        for h in handles {
            lat.extend(h.join().expect("client thread panicked")?);
        }
        let wall = wall0.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.total_cmp(b));
        points.push(Point {
            offered_qps: qps,
            p50_ms: percentile(&lat, 0.50),
            p99_ms: percentile(&lat, 0.99),
            achieved_qps: lat.len() as f64 / wall,
        });
    }
    ServeClient::connect(&addr)?.drain()?;
    serving.join()?;
    Ok(points)
}

fn render(points: &[Point]) -> String {
    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"offered_qps\": {:.1}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \
                 \"achieved_qps\": {:.1}}}",
                p.offered_qps, p.p50_ms, p.p99_ms, p.achieved_qps
            )
        })
        .collect();
    format!("[\n{}\n  ]", rows.join(",\n"))
}

fn main() -> anyhow::Result<()> {
    // The served model is a weight artifact, not a trained run: freshly
    // initialized tiny-preset parameters exercise the exact same path.
    let arch = ArchSpec::preset("tiny").expect("tiny preset exists");
    let dir = std::env::temp_dir().join(format!("convdist_bench_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("model.ckpt");
    Checkpoint {
        step: 0,
        arch_label: arch.label(),
        params: Params::init(&arch, 7)?.to_named(),
        velocity: vec![],
    }
    .save(&ckpt)?;

    let off = run_mode(&ckpt, false)?;
    let on = run_mode(&ckpt, true)?;
    let _ = std::fs::remove_dir_all(&dir);

    let (off_sat, on_sat) = (off.last().unwrap(), on.last().unwrap());
    let mut json = String::new();
    writeln!(json, "{{")?;
    writeln!(json, "  \"name\": \"serve_dynamic_batcher\",")?;
    writeln!(json, "  \"arch\": \"tiny\",")?;
    writeln!(json, "  \"connections\": {CONNECTIONS},")?;
    writeln!(json, "  \"requests_per_point\": {},", CONNECTIONS * REQUESTS_PER_CONN)?;
    writeln!(json, "  \"batcher_off\": {},", render(&off))?;
    writeln!(json, "  \"batcher_on\": {},", render(&on))?;
    writeln!(json, "  \"saturating_p50_off_ms\": {:.4},", off_sat.p50_ms)?;
    writeln!(json, "  \"saturating_p50_on_ms\": {:.4}", on_sat.p50_ms)?;
    writeln!(json, "}}")?;
    std::fs::write("BENCH_serve.json", &json)?;

    for (label, pts) in [("batcher off", &off), ("batcher on ", &on)] {
        for p in pts.iter() {
            println!(
                "{label}  offered {:>6.1} qps  p50 {:>8.3} ms  p99 {:>8.3} ms  achieved {:>6.1} qps",
                p.offered_qps, p.p50_ms, p.p99_ms, p.achieved_qps
            );
        }
    }
    println!(
        "BENCH_serve.json written: saturating p50 {:.3} ms (batch-of-one) vs {:.3} ms (batched)",
        off_sat.p50_ms, on_sat.p50_ms
    );
    anyhow::ensure!(
        on_sat.p50_ms <= off_sat.p50_ms * 1.10,
        "dynamic batching must not lose to batch-of-one at saturating load: \
         p50 {:.3} ms (on) vs {:.3} ms (off)",
        on_sat.p50_ms,
        off_sat.p50_ms
    );
    Ok(())
}
