//! `cargo run --release --example bench_sched`
//!
//! Emits `BENCH_sched.json`: the static-vs-adaptive-vs-oracle step-time
//! trajectory of the scheduler simulator's CI scenario (an equal 4-device
//! fleet, one device degrading 8x mid-run — `sim::trajectory`).  CI uploads
//! the file as a workflow artifact so re-shard payoff and re-partition
//! latency are tracked over time.

use std::fmt::Write as _;

use convdist::sim::trajectory::{simulate_adaptive, tail_means, TrajectorySpec};

fn main() -> anyhow::Result<()> {
    let spec = TrajectorySpec::ci_default();
    let points = simulate_adaptive(&spec)?;
    let (s_tail, a_tail, o_tail) = tail_means(&points, 10);
    let recovered = ((s_tail - a_tail) / (s_tail - o_tail).max(1e-12)).clamp(0.0, 1.0);
    let repartitions = points.iter().filter(|p| p.repartitioned).count();

    let mut json = String::new();
    writeln!(json, "{{")?;
    writeln!(json, "  \"name\": \"sched_adaptive_trajectory\",")?;
    writeln!(json, "  \"arch\": \"{}@{}\",", spec.arch.label(), spec.arch.batch)?;
    writeln!(
        json,
        "  \"devices\": [{}],",
        spec.gflops.iter().map(|g| format!("{g}")).collect::<Vec<_>>().join(", ")
    )?;
    writeln!(
        json,
        "  \"degrade\": {{\"device\": {}, \"at_step\": {}, \"factor\": {}}},",
        spec.degrade_device, spec.degrade_at_step, spec.degrade_factor
    )?;
    writeln!(json, "  \"trajectory\": [")?;
    for (i, p) in points.iter().enumerate() {
        let comma = if i + 1 < points.len() { "," } else { "" };
        writeln!(
            json,
            "    {{\"step\": {}, \"static\": {:.6}, \"adaptive\": {:.6}, \"oracle\": {:.6}, \"repartitioned\": {}}}{comma}",
            p.step, p.static_secs, p.adaptive_secs, p.oracle_secs, p.repartitioned
        )?;
    }
    writeln!(json, "  ],")?;
    writeln!(json, "  \"summary\": {{")?;
    writeln!(json, "    \"static_tail_mean_s\": {s_tail:.6},")?;
    writeln!(json, "    \"adaptive_tail_mean_s\": {a_tail:.6},")?;
    writeln!(json, "    \"oracle_tail_mean_s\": {o_tail:.6},")?;
    writeln!(json, "    \"repartitions\": {repartitions},")?;
    writeln!(json, "    \"recovered_fraction\": {recovered:.4}")?;
    writeln!(json, "  }}")?;
    writeln!(json, "}}")?;

    std::fs::write("BENCH_sched.json", &json)?;
    println!(
        "BENCH_sched.json written: static tail {s_tail:.4}s, adaptive tail {a_tail:.4}s, \
         oracle tail {o_tail:.4}s ({} re-shards, {:.0}% of oracle speedup recovered)",
        repartitions,
        100.0 * recovered
    );
    anyhow::ensure!(repartitions >= 1, "the CI scenario must trigger a re-shard");
    anyhow::ensure!(a_tail <= s_tail, "adaptive must not lose to static after degradation");
    Ok(())
}
