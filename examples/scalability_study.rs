//! Scalability study — the paper's §5.3.4 extrapolation, regenerated.
//!
//! Runs the analytic simulator (Eq. 1 partition + Eq. 2 wire volume +
//! calibrated comp share) out to 32 CPU / 32 GPU / 128 mobile-GPU nodes and
//! prints the Figure 9/10/13 series, optionally calibrated to THIS
//! machine's measured conv throughput (pass `--calibrate`).
//!
//! ```sh
//! cargo run --release --example scalability_study [--calibrate]
//! ```

use convdist::devices::{mobile_gpu, paper_cpus, paper_gpus, sample_cluster};
use convdist::runtime::Runtime;
use convdist::sim::{simulate_step, ArchShape, SimConfig};
use convdist::tensor::{Pcg32, Tensor};

/// Measure this container's effective conv GFLOPS with the probe
/// executable, returning a scale factor for the device catalogs.
fn measured_scale() -> anyhow::Result<f64> {
    let rt = Runtime::open(convdist::artifacts_dir())?;
    let p = rt.arch().probe.clone();
    let mut rng = Pcg32::seed(3);
    let x = Tensor::randn(&[p.batch, p.in_ch, p.img, p.img], &mut rng);
    let w = Tensor::randn(&[p.k, p.in_ch, p.kh, p.kw], &mut rng);
    let b = Tensor::zeros(&[p.k]);
    let args = [x.into(), w.into(), b.into()];
    let _ = rt.execute("probe", &args)?;
    let mut best = f64::MAX;
    for _ in 0..5 {
        let (_, d) = rt.execute_timed("probe", &args)?;
        best = best.min(d.as_secs_f64());
    }
    let gflops = p.flops as f64 / best / 1e9;
    // PC1 (the paper's CPU master) is the 20-GFLOPS anchor.
    Ok(gflops / 20.0)
}

fn main() -> anyhow::Result<()> {
    let calibrate = std::env::args().any(|a| a == "--calibrate");
    let scale = if calibrate { measured_scale()? } else { 1.0 };
    if calibrate {
        println!("calibrated: local probe => gflops scale {scale:.4}\n");
    }

    let cases = [
        ("Fig 9a: CPUs, 50:500 @ 64", ArchShape::new(50, 500, 64), paper_cpus(), 20.0),
        ("Fig 9b: CPUs, 500:1500 @ 1024", ArchShape::new(500, 1500, 1024), paper_cpus(), 20.0),
        ("Fig 10: GPUs, 500:1500 @ 1024", ArchShape::new(500, 1500, 1024), paper_gpus(), 38.0),
    ];
    for (title, arch, catalog, master_cpu) in cases {
        let mut cfg = SimConfig::paper(arch);
        cfg.master_cpu_gflops = master_cpu;
        cfg.gflops_scale = scale;
        let mut rng = Pcg32::seed(0x5CA1E);
        let cluster = sample_cluster(&catalog, 32, &mut rng);
        println!("{title}");
        println!("  nodes   comm s    conv s    comp s   total s  speedup");
        let t1 = simulate_step(&cfg, &cluster[..1]).total().as_secs_f64();
        for n in [1usize, 2, 4, 8, 16, 24, 32] {
            let b = simulate_step(&cfg, &cluster[..n]);
            println!(
                "  {n:>5} {:>8.2} {:>9.2} {:>9.2} {:>9.2} {:>8.2}x",
                b.comm.as_secs_f64(),
                b.conv.as_secs_f64(),
                b.comp.as_secs_f64(),
                b.total().as_secs_f64(),
                t1 / b.total().as_secs_f64()
            );
        }
        println!();
    }

    // Fig 13: mobile-GPU fleet with a desktop master, out to 128 nodes.
    println!("Fig 13: mobile GPUs (desktop master), 500:1500 @ 1024");
    let arch = ArchShape::new(500, 1500, 1024);
    let mut cfg = SimConfig::paper(arch);
    cfg.master_cpu_gflops = 38.0;
    cfg.gflops_scale = scale;
    let mut fleet = vec![paper_gpus()[0].clone()];
    fleet.extend(std::iter::repeat(mobile_gpu()).take(127));
    println!("  nodes  total s  speedup");
    let t1 = simulate_step(&cfg, &fleet[..1]).total().as_secs_f64();
    for n in [1usize, 2, 8, 32, 64, 128] {
        let b = simulate_step(&cfg, &fleet[..n]);
        println!("  {n:>5} {:>8.2} {:>8.2}x", b.total().as_secs_f64(), t1 / b.total().as_secs_f64());
    }
    println!("\n(paper: speedup stabilizes after ~8 desktop nodes; 32 mobile GPUs are not\n enough to match desktop clusters, 128 close the gap given bandwidth)");
    Ok(())
}
