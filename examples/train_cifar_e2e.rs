//! END-TO-END DRIVER (DESIGN.md deliverable (b), EXPERIMENTS.md §E2E).
//!
//! Trains the paper's CNN on a CIFAR-10-like workload for a few hundred
//! steps on a real distributed cluster (master + 2 workers over the wire
//! protocol), logging the loss curve, and proves all layers compose:
//!
//!   L1 conv kernels -> L2 runtime executables -> L3 master/worker protocol
//!   -> Eq. 1 partitioning -> SGD — all composed by one `SessionBuilder`,
//!
//! then cross-checks the final parameters against single-device training
//! (the paper's "without affecting the classification performance" claim)
//! and reports held-out accuracy vs 10-class chance.
//!
//! Uses the real CIFAR-10 binaries if present under
//! `data/cifar-10-batches-bin/`, else the synthetic class-conditioned set
//! (substitution documented in DESIGN.md §2).
//!
//! ```sh
//! cargo run --release --example train_cifar_e2e [steps] [arch]
//! # e.g. the 3-conv preset the layer-graph API opened up:
//! cargo run --release --example train_cifar_e2e 50 deep_cifar
//! ```
//!
//! `arch` names an `ArchSpec` preset (default | tiny | deep_cifar |
//! tiny_deep); when given, the whole cluster runs that synthesized graph on
//! the native backend (bypassing any `artifacts/manifest.json`) — the
//! builder hands the same graph to master and workers.

use std::time::Instant;

use convdist::baselines::SingleDeviceTrainer;
use convdist::config::TrainerConfig;
use convdist::data::default_dataset;
use convdist::devices::Throttle;
use convdist::metrics::Breakdown;
use convdist::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let preset = std::env::args().nth(2);
    let cfg = TrainerConfig {
        steps,
        lr: 0.03,
        momentum: 0.9,
        weight_decay: 1e-4,
        ..Default::default()
    };

    // Workers must resolve the same graph as the master: the builder's arch
    // source travels to in-proc workers by argument, never ambient state.
    let builder = || -> convdist::session::SessionBuilder {
        let b = SessionBuilder::new()
            .trainer(cfg.clone())
            .workers(&[Throttle::none(), Throttle::none()]);
        match &preset {
            Some(name) => b.arch_preset(name.clone()),
            None => b,
        }
    };

    // --- distributed run: master + 2 workers --------------------------------
    let mut dist = builder().build()?;
    let rt = dist.runtime().clone();
    let arch = rt.arch().clone();
    println!(
        "e2e: arch {} ({} conv layers) batch {} — {} steps, lr {}, momentum {}",
        arch.label(),
        arch.num_convs(),
        arch.batch,
        cfg.steps,
        cfg.lr,
        cfg.momentum
    );
    println!("calibration: {:?}", dist.trainer().probe_times());

    let mut ds = default_dataset(arch.img, arch.in_ch, arch.num_classes, cfg.seed);
    let mut curve: Vec<(usize, f32)> = Vec::new();
    let mut cum = Breakdown::default();
    let t0 = Instant::now();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step)?;
        let res = dist.step(&batch)?;
        cum.add(&res.breakdown);
        if step % 10 == 0 || step + 1 == cfg.steps {
            curve.push((step, res.loss));
            println!("step {step:>4}  loss {:.4}  {}", res.loss, res.breakdown);
        }
    }
    let wall = t0.elapsed();

    // --- loss curve ----------------------------------------------------------
    println!("\nloss curve (step, loss):");
    for (s, l) in &curve {
        let bar = "#".repeat((l * 18.0) as usize);
        println!("  {s:>4}  {l:7.4}  {bar}");
    }
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    anyhow::ensure!(last < first, "loss must decrease: {first} -> {last}");

    // --- held-out accuracy ---------------------------------------------------
    let held_out = ds.batch(arch.batch, cfg.steps + 17)?;
    let acc = dist.eval(&held_out)?;
    println!(
        "\nheld-out accuracy: {:.1}% (chance {:.1}%)",
        acc * 100.0,
        100.0 / arch.num_classes as f32
    );

    // --- single-device cross-check (same seed, few steps) -------------------
    let check_steps = steps.min(5);
    let mut single = SingleDeviceTrainer::new(rt.clone(), &cfg, Throttle::none())?;
    let mut ds2 = default_dataset(arch.img, arch.in_ch, arch.num_classes, cfg.seed);
    let mut dist2 = builder().build()?;
    let mut worst = 0f32;
    for step in 0..check_steps {
        let batch = ds2.batch(arch.batch, step)?;
        let (sl, _) = single.step(&batch)?;
        let r = dist2.step(&batch)?;
        worst = worst.max((sl - r.loss).abs());
    }
    let pdiff = dist2.trainer().params.max_abs_diff(&single.params)?;
    println!(
        "distributed vs single-device ({check_steps} steps): max |Δloss| {worst:.2e}, \
         max |Δparam| {pdiff:.2e}"
    );
    anyhow::ensure!(pdiff < 5e-3, "distributed training diverged from single-device");

    println!("\ntotals: wall {:.1}s  |  {}", wall.as_secs_f64(), cum);
    dist.shutdown()?;
    dist2.shutdown()?;
    println!("e2e OK — record in EXPERIMENTS.md §E2E");
    Ok(())
}
