#!/usr/bin/env sh
# Full CI gate, mirrored by .github/workflows/ci.yml.
# Runs on the default (native) feature set — fully offline.
set -eux

cargo fmt --all --check
# -D warnings plus a curated always-deny subset: debug/stub macros and
# mem::forget must never land, even if a future edit allows the lint group.
cargo clippy --all-targets -- -D warnings -D clippy::dbg_macro -D clippy::todo \
  -D clippy::unimplemented -D clippy::mem_forget
cargo build --release
cargo test -q
# Adaptive-scheduler suite under the throttled in-proc cluster (also part
# of `cargo test` above; named here so a renamed/deleted target fails loud).
cargo test -q --test adaptive_sched
# Layer-graph API gate: 3-conv distributed-vs-single equivalence + e2e
# gradcheck (also part of `cargo test`; named so the target stays alive).
cargo test -q --test layer_graph
# Session API gate: builder-vs-legacy bit-for-bit equivalence + the
# checkpoint/resume scenario (also part of `cargo test`; named so the
# target stays alive).
cargo test -q --test session
# Static-analyzer gate (DESIGN.md §10): the bad_graphs corpus must fail
# with its documented codes, shipped presets/configs must check clean.
cargo test -q --test static_analysis
# Observability gate (DESIGN.md §11): traced adaptive run in causal order,
# trace.json vs step breakdowns, report rendering (also part of `cargo
# test`; named so the target stays alive).
cargo test -q --test obs
# `convdist check` must pass (exit 0) on everything the repo ships.
for arch in default tiny deep_cifar tiny_deep; do
  cargo run --release -- check --arch "$arch"
done
for cfg in examples/configs/*.json; do
  cargo run --release -- check --config "$cfg"
done
# Config-driven end-to-end smoke: one full session (arch preset, in-proc
# fleet, eval) composed entirely from the checked-in experiment config —
# fully traced, then the run log must validate and re-render via `report`.
rm -rf ci_trace
cargo run --release -- run --config examples/configs/smoke.json --trace ci_trace --metrics
test -s ci_trace/run.jsonl
test -s ci_trace/trace.json
cargo run --release -- report ci_trace/run.jsonl
# Adaptive end-to-end: the config pre-flight plus an adaptive-enabled run.
cargo run --release -- run --config examples/configs/adaptive.json
# Static-vs-adaptive step-time trajectory from the scheduler simulator;
# uploaded as a workflow artifact for trend tracking.
cargo run --release --example bench_sched
test -s BENCH_sched.json
# Naive vs blocked GEMM GFLOP/s on the paper's conv shapes; enforces the
# >= 3x engine speedup gate and is uploaded as a workflow artifact.
cargo run --release --example bench_gemm
test -s BENCH_gemm.json
# Tracing overhead gate (< 2% of step time on a sleep-dominated fleet);
# uploaded as a workflow artifact for trend tracking.
cargo run --release --example bench_obs
test -s BENCH_obs.json
# The PJRT path must keep compiling even though it is an offline stub.
cargo check --features pjrt
# Sanitizer pass over the unsafe core (linalg byte-level GEMM paths with
# SIMD forced off, proto wire-format byte casts) — runs where a nightly
# miri is available; the GitHub workflow provisions one in a dedicated job.
if cargo miri --version >/dev/null 2>&1; then
  CONVDIST_NO_SIMD=1 cargo miri test -p convdist --lib -- linalg proto
fi
