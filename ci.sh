#!/usr/bin/env sh
# Full CI gate, mirrored by .github/workflows/ci.yml.
# Runs on the default (native) feature set — fully offline.
set -eux

cargo fmt --all --check
cargo clippy --all-targets -- -D warnings
cargo build --release
cargo test -q
# The PJRT path must keep compiling even though it is an offline stub.
cargo check --features pjrt
