#!/usr/bin/env sh
# Full CI gate, mirrored by .github/workflows/ci.yml.
# Runs on the default (native) feature set — fully offline.
set -eux

cargo fmt --all --check
cargo clippy --all-targets -- -D warnings
cargo build --release
cargo test -q
# Adaptive-scheduler suite under the throttled in-proc cluster (also part
# of `cargo test` above; named here so a renamed/deleted target fails loud).
cargo test -q --test adaptive_sched
# Layer-graph API gate: 3-conv distributed-vs-single equivalence + e2e
# gradcheck (also part of `cargo test`; named so the target stays alive).
cargo test -q --test layer_graph
# Session API gate: builder-vs-legacy bit-for-bit equivalence + the
# checkpoint/resume scenario (also part of `cargo test`; named so the
# target stays alive).
cargo test -q --test session
# Config-driven end-to-end smoke: one full session (arch preset, in-proc
# fleet, eval) composed entirely from the checked-in experiment config.
cargo run --release -- run --config examples/configs/smoke.json
# Static-vs-adaptive step-time trajectory from the scheduler simulator;
# uploaded as a workflow artifact for trend tracking.
cargo run --release --example bench_sched
test -s BENCH_sched.json
# Naive vs blocked GEMM GFLOP/s on the paper's conv shapes; enforces the
# >= 3x engine speedup gate and is uploaded as a workflow artifact.
cargo run --release --example bench_gemm
test -s BENCH_gemm.json
# The PJRT path must keep compiling even though it is an offline stub.
cargo check --features pjrt
