#!/usr/bin/env sh
# Full CI gate, mirrored by .github/workflows/ci.yml.
# Runs on the default (native) feature set — fully offline.
set -eux

cargo fmt --all --check
# -D warnings plus a curated always-deny subset: debug/stub macros and
# mem::forget must never land, even if a future edit allows the lint group.
cargo clippy --all-targets -- -D warnings -D clippy::dbg_macro -D clippy::todo \
  -D clippy::unimplemented -D clippy::mem_forget
cargo build --release
cargo test -q
# Adaptive-scheduler suite under the throttled in-proc cluster (also part
# of `cargo test` above; named here so a renamed/deleted target fails loud).
cargo test -q --test adaptive_sched
# Layer-graph API gate: 3-conv distributed-vs-single equivalence + e2e
# gradcheck (also part of `cargo test`; named so the target stays alive).
cargo test -q --test layer_graph
# Session API gate: builder-vs-legacy bit-for-bit equivalence + the
# checkpoint/resume scenario (also part of `cargo test`; named so the
# target stays alive).
cargo test -q --test session
# Static-analyzer gate (DESIGN.md §10): the bad_graphs corpus must fail
# with its documented codes, shipped presets/configs must check clean.
cargo test -q --test static_analysis
# Replica-tier gate (DESIGN.md §14): 2-replica-vs-single-fleet equivalence
# and the master-vs-ring bit-for-bit contract need a pinned thread count
# (the tests also pin rayon internally; the env var keeps a pre-built pool
# from another harness from widening it).
RAYON_NUM_THREADS=1 cargo test -q --test replica
# Observability gate (DESIGN.md §11): traced adaptive run in causal order,
# trace.json vs step breakdowns, report rendering (also part of `cargo
# test`; named so the target stays alive).
cargo test -q --test obs
# `convdist check` must pass (exit 0) on everything the repo ships.
for arch in default tiny deep_cifar tiny_deep; do
  cargo run --release -- check --arch "$arch"
done
for cfg in examples/configs/*.json; do
  cargo run --release -- check --config "$cfg"
done
# Config-driven end-to-end smoke: one full session (arch preset, in-proc
# fleet, eval) composed entirely from the checked-in experiment config —
# fully traced, then the run log must validate and re-render via `report`.
rm -rf ci_trace
cargo run --release -- run --config examples/configs/smoke.json --trace ci_trace --metrics
test -s ci_trace/run.jsonl
test -s ci_trace/trace.json
cargo run --release -- report ci_trace/run.jsonl
# Cross-run regression gate (DESIGN.md §12): the committed golden baseline
# must compare clean against itself; the committed slow variant (a +50%
# injected slowdown) must trip the gate's non-zero exit; and the fresh
# smoke log must re-parse through the same pipeline (`top` + a machine
# readable self-compare, kept as a workflow artifact next to the trace).
cargo run --release -- compare rust/tests/fixtures/golden_run.jsonl \
  rust/tests/fixtures/golden_run.jsonl
if cargo run --release -- compare rust/tests/fixtures/golden_run.jsonl \
  rust/tests/fixtures/golden_run_slow.jsonl --threshold 20; then
  echo "compare gate failed to trip on the slow fixture" >&2
  exit 1
fi
cargo run --release -- top ci_trace/run.jsonl
cargo run --release -- compare ci_trace/run.jsonl ci_trace/run.jsonl \
  --format jsonl >ci_trace/compare.jsonl
test -s ci_trace/compare.jsonl
# Live fleet endpoint smoke (DESIGN.md §12): re-run the smoke config with a
# metrics listener on an ephemeral port, scrape it with curl mid-run, and
# require the exposition header plus per-device health gauges.  Skipped
# where curl is absent (the GitHub runners always have it).
if command -v curl >/dev/null 2>&1; then
  rm -f live_run.out live_metrics.txt
  cargo run --release -- run --config examples/configs/smoke.json --steps 60 \
    --metrics-addr 127.0.0.1:0 >live_run.out 2>&1 &
  live_pid=$!
  i=0
  while [ "$i" -lt 100 ]; do
    addr=$(sed -n 's|.*live metrics: http://\([0-9.:]*\)/metrics.*|\1|p' live_run.out | head -n 1)
    if [ -n "$addr" ] && curl -fsS "http://$addr/metrics" >live_metrics.txt 2>/dev/null &&
      grep -q 'convdist_health{' live_metrics.txt; then
      break
    fi
    i=$((i + 1))
    sleep 0.1
  done
  if ! grep -q 'convdist_health{' live_metrics.txt 2>/dev/null; then
    kill "$live_pid" 2>/dev/null || true
    echo "live metrics endpoint never served the health gauges" >&2
    exit 1
  fi
  grep -q '^convdist_up 1' live_metrics.txt
  grep -q '^# TYPE convdist_steps counter' live_metrics.txt
  wait "$live_pid"
  rm -f live_run.out live_metrics.txt
fi
# Serving gate (DESIGN.md §13): bitwise serve-vs-eval equivalence under a
# pinned thread count, then a loopback deployment — train the serve-config
# checkpoint, boot `convdist serve` with dynamic batching and a metrics
# listener, fire concurrent `convdist infer` clients, require a non-empty
# request-latency histogram on the scrape, drain, and wait for clean exit.
RAYON_NUM_THREADS=1 cargo test -q --test serve
rm -f serve.ckpt serve_run.out serve_metrics.txt
cargo run --release -- run --config examples/configs/serve.json --save serve.ckpt
cargo run --release -- serve --ckpt serve.ckpt --config examples/configs/serve.json \
  --addr 127.0.0.1:0 --metrics-addr 127.0.0.1:0 >serve_run.out 2>&1 &
serve_pid=$!
i=0
saddr=
while [ "$i" -lt 100 ]; do
  saddr=$(sed -n 's|.*serving on \([0-9.:]*\) .*|\1|p' serve_run.out | head -n 1)
  [ -n "$saddr" ] && break
  i=$((i + 1))
  sleep 0.1
done
if [ -z "$saddr" ]; then
  kill "$serve_pid" 2>/dev/null || true
  echo "convdist serve never printed its bound address" >&2
  cat serve_run.out >&2
  exit 1
fi
cargo run --release -- infer --addr "$saddr" --arch tiny --requests 8 --concurrency 4
if command -v curl >/dev/null 2>&1; then
  maddr=$(sed -n 's|.*live metrics: http://\([0-9.:]*\)/metrics.*|\1|p' serve_run.out | head -n 1)
  curl -fsS "http://$maddr/metrics" >serve_metrics.txt
  grep -q '^convdist_serve_request_ms_count [1-9]' serve_metrics.txt
  grep -q '^convdist_serve_queue_depth_count [1-9]' serve_metrics.txt
fi
cargo run --release -- infer --addr "$saddr" --arch tiny --requests 1 --drain
wait "$serve_pid"
rm -f serve.ckpt serve_run.out serve_metrics.txt
# Dynamic-batcher bench (p50/p99 vs offered QPS, batcher on vs off; the
# batched p50 must not lose at saturation); uploaded as a CI artifact.
cargo run --release --example bench_serve
test -s BENCH_serve.json
# Adaptive end-to-end: the config pre-flight plus an adaptive-enabled run.
cargo run --release -- run --config examples/configs/adaptive.json
# Static-vs-adaptive step-time trajectory from the scheduler simulator;
# uploaded as a workflow artifact for trend tracking.
cargo run --release --example bench_sched
test -s BENCH_sched.json
# Replica sweep (1/2/4 fleets, master vs ring all-reduce): step time and
# fabric bytes, with the ring<=master wire-cost gate enforced inside;
# uploaded as a workflow artifact for trend tracking.
cargo run --release --example bench_replicas
test -s BENCH_replicas.json
# Replica end-to-end over the CLI: a short ring-all-reduce run driven
# entirely by the checked-in config (which the check loop above already
# pre-flights through the C010 gate).
cargo run --release -- run --config examples/configs/replicas.json --steps 3
# Naive vs blocked GEMM GFLOP/s on the paper's conv shapes; enforces the
# >= 3x engine speedup gate and is uploaded as a workflow artifact.
cargo run --release --example bench_gemm
test -s BENCH_gemm.json
# Tracing overhead gate (< 2% of step time on a sleep-dominated fleet);
# uploaded as a workflow artifact for trend tracking.
cargo run --release --example bench_obs
test -s BENCH_obs.json
# The PJRT path must keep compiling even though it is an offline stub.
cargo check --features pjrt
# Sanitizer pass over the unsafe core (linalg byte-level GEMM paths with
# SIMD forced off, proto wire-format byte casts) — runs where a nightly
# miri is available; the GitHub workflow provisions one in a dedicated job.
if cargo miri --version >/dev/null 2>&1; then
  CONVDIST_NO_SIMD=1 cargo miri test -p convdist --lib -- linalg proto
fi
